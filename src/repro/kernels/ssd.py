"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

The SSD chunked scan is two matmul-shaped contractions per chunk plus a tiny
sequential state recurrence — ideal MXU work if the chunk is tiled into VMEM.

Grid: (batch, num_chunks). TPU grids execute sequentially (row-major, last
dim fastest), so the inter-chunk state carry lives in a VMEM scratch buffer
(H, P, N) f32 that persists across the chunk axis and is reset whenever a
new batch row begins — the same scratch-as-carry idiom as the flash kernel.

Per grid step, with one (chunk × heads) tile resident in VMEM:
  L       = exp(segsum(dt*A))                 (H, cl, cl) intra-chunk decay
  y_intra = (C Bᵀ ∘ L) @ (dt*x)               batched (cl,cl)@(cl,P) per head
  y_inter = (C @ state_prev) * in_decay        (cl,N)@(N,P) per head
  state   = state_prev * chunk_decay + (decay_to_end * B)ᵀ @ (dt*x)

The pure-jnp oracle is models/ssm.ssd_reference (re-exported in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,  # (1, cl, H, P)
    dt_ref,  # (1, cl, H) f32
    a_ref,  # (H,) f32
    b_ref,  # (1, cl, N)
    c_ref,  # (1, cl, N)
    y_ref,  # (1, cl, H, P)
    state_scr,  # (H, P, N) f32 carry across chunks
    *,
    cl: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)  # (cl, H, P)
    dt = dt_ref[0].astype(jnp.float32)  # (cl, H)
    A = a_ref[...].astype(jnp.float32)  # (H,)
    Bm = b_ref[0].astype(jnp.float32)  # (cl, N)
    Cm = c_ref[0].astype(jnp.float32)  # (cl, N)

    dA = dt * A[None, :]  # (cl, H)
    dA_cum = jnp.cumsum(dA, axis=0)  # (cl, H)
    xdt = x * dt[..., None]  # (cl, H, P)

    # intra-chunk: y[i] = sum_{j<=i} C_i·B_j exp(dA_cum_i - dA_cum_j) xdt_j
    seg = dA_cum.T[:, :, None] - dA_cum.T[:, None, :]  # (H, cl, cl)
    tril = (
        jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    )
    L = jnp.where(tril[None], jnp.exp(seg), 0.0)  # (H, cl, cl)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cl, cl)
    M = scores[None] * L  # (H, cl, cl)
    xdt_h = xdt.transpose(1, 0, 2)  # (H, cl, P)
    y_intra = jax.lax.dot_general(
        M, xdt_h, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (H, cl, P)

    # inter-chunk: y[i] += (C_i @ state_prev_h) * exp(dA_cum_i)
    state = state_scr[...]  # (H, P, N)
    y_inter = jax.lax.dot_general(
        jnp.broadcast_to(Cm[None], (state.shape[0], cl, Cm.shape[1])),
        state,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # (H, cl, P)
    in_decay = jnp.exp(dA_cum).T  # (H, cl)
    y = y_intra + y_inter * in_decay[:, :, None]
    y_ref[0] = y.transpose(1, 0, 2).astype(y_ref.dtype)  # (cl, H, P)

    # state update
    chunk_decay = jnp.exp(dA_cum[-1, :])  # (H,)
    decay_to_end = jnp.exp(dA_cum[-1:, :] - dA_cum)  # (cl, H)
    bw = Bm[None, :, :] * decay_to_end.T[:, :, None]  # (H, cl, N)
    new_contrib = jax.lax.dot_general(
        xdt_h, bw, (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (H, P, N)
    state_scr[...] = state * chunk_decay[:, None, None] + new_contrib


def ssd_bshp(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) f32
    A: jax.Array,  # (H,) f32
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    cl = min(chunk, S)
    assert S % cl == 0, (S, cl)
    nc = S // cl

    kernel = functools.partial(_kernel, cl=cl)
    out = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, cl, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, cl, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, cl, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, cl, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, cl, H, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), A.astype(jnp.float32), Bm, Cm)
    return out
