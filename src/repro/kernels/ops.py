"""jit'd public wrappers around the Pallas kernels.

These adapt the model-layer layouts ((B, S, H, Dh) activations) to the
kernel layouts, pick block sizes, and fall back to interpret mode off-TPU
(so the same call sites work in CPU tests; the dry-run lowers the jnp
reference path instead — see DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .ssd import ssd_bshp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, Dh) — model layout
    k: jax.Array,  # (B, Sk, KV, Dh)
    v: jax.Array,  # (B, Sk, KV, Dh)
    bias: Optional[jax.Array] = None,  # ignored: masks via causal/window
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    del bias
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(
        qt,
        kt,
        vt,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=not _on_tpu(),
    )
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 64,
) -> jax.Array:
    return ssd_bshp(x, dt, A, Bm, Cm, chunk=chunk, interpret=not _on_tpu())
