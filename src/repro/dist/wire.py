"""Job wire protocol for the process backend (DESIGN.md §11).

A remote job carries two payload kinds across the parent↔worker pipe:

* **function wire** (:func:`dumps_fn` / :func:`loads_fn`) — the task body.
  Plain picklable callables (module-level functions, ``functools.partial``
  of them) go through stdlib pickle. Lambdas and closures — the dominant
  body idiom in this codebase — fail stdlib pickle, so they fall back to a
  *code-object wire*: the function's code is ``marshal``-ed, and its
  defaults and closure cells are captured **by value** (recursively, so a
  lambda closing over another lambda ships too). The worker rebuilds the
  function against the globals of its defining module (``sys.modules``
  first — under the default ``fork`` start method the module object
  already exists in the child — then a regular import).

  The by-value capture is the contract's sharp edge: a remote body sees a
  *snapshot* of its closure taken at submission, and mutations it makes
  never travel back. Loop/condition state must therefore live in
  scheduler-side bodies (conditions always run in-parent) or flow along
  dataflow edges. DESIGN.md §11 spells the rule out.

* **value wire** (:func:`dumps_value` / :func:`loads_value`) — edge values
  (dataflow arguments and results). Most objects go through pickle;
  numpy/jax arrays at or above the arena threshold are carried through a
  :class:`~repro.dist.shm_arena.ShmArena` block instead — the descriptor
  crosses the pipe, the bytes cross shared memory (zero-copy on the read
  side). Callables nested in values reuse the function wire.

:class:`UnpicklableTaskError` is the submit-time verdict for a body that
cannot be shipped: raised eagerly by ``ProcessPool`` for tasks with
``affinity="remote"`` so the caller learns at submit, not mid-run.

Closures round-trip with their captured state::

    >>> from repro.dist.wire import dumps_fn, loads_fn
    >>> def make(k):
    ...     return lambda x: x * k
    >>> loads_fn(dumps_fn(make(6)))(7)
    42
"""
from __future__ import annotations

import importlib
import marshal
import pickle
import sys
import threading
import types
from typing import Any, Optional

__all__ = [
    "UnpicklableTaskError",
    "picklability_error",
    "dumps_fn",
    "loads_fn",
    "dumps_value",
    "loads_value",
    "dumps_exception",
    "loads_exception",
]

# wire tags (first element of every payload tuple)
_PICKLE = 0  # stdlib pickle bytes
_CODE = 1  # marshalled code object + captured defaults/cells/globals
_PARTIAL = 2  # functools.partial: (fn-wire, args-wire, kwargs-wire)
_SHM = 3  # shared-memory array descriptor (ArrayRef)
_TUPLE = 4  # tuple of value-wires (used for argument packs)
_MODULE = 5  # module captured in a cell/global, shipped by name
_DICT = 6  # dict of value-wires (batch dicts holding large arrays)
_LIST = 7  # list of value-wires

_CONTAINER_SCAN_MAX = 64  # don't deep-scan huge containers for arena arrays


def _referenced_globals(code: Any) -> set:
    """Global names a code object (or any code nested in it) can load —
    the subset of ``fn.__globals__`` worth shipping by value."""
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            names |= _referenced_globals(const)
    return names


_dump_guard = threading.local()  # breaks self-referential global cycles


class UnpicklableTaskError(TypeError):
    """A task body (or a value it captures) cannot be serialized for a
    worker process.

    Raised at submit time for ``affinity="remote"`` tasks; tasks with the
    default ``affinity="any"`` fall back to in-parent execution instead.
    """


def _dumps_cell(value: Any) -> Any:
    """Wire one captured value (default or closure cell): pickle first,
    modules by name, function wire for callables pickle rejects — and for
    ``__main__`` functions, which pickle only *by reference* and so would
    dangle in a worker forked before their definition."""
    if isinstance(value, types.ModuleType):
        return (_MODULE, value.__name__)
    if isinstance(value, types.FunctionType) and value.__module__ == "__main__":
        return dumps_fn(value)
    try:
        return (_PICKLE, pickle.dumps(value))
    except Exception:
        if callable(value):
            return dumps_fn(value)
        raise


def picklability_error(fn: Any) -> Optional[str]:
    """Non-raising probe: would :func:`dumps_fn` accept this body?

    Returns ``None`` when ``fn`` can cross the §11 process wire, or the
    :class:`UnpicklableTaskError` message naming the offending capture
    when it cannot. This is the static-analysis entry point
    (``repro.analysis.lint``'s *remote-unpicklable* rule) — the same
    serializer the real offload path runs, invoked at lint time instead
    of at dispatch, so a ``affinity="remote"`` body that would die in
    flight is reported before the graph ever runs. The probe serializes
    (it does not ship), so it is side-effect free but pays the wire cost
    once per probed body.
    """
    try:
        dumps_fn(fn)
    except UnpicklableTaskError as exc:
        return str(exc)
    except Exception as exc:  # defensive: any serializer failure is a verdict
        return f"{type(exc).__name__}: {exc}"
    return None


def dumps_fn(fn: Any) -> tuple:
    """Serialize a callable for a worker process.

    Importable functions go by pickle reference; lambdas, closures and
    ``__main__``-level functions go by value through the code wire (a
    pickle *reference* to ``__main__`` dangles in any worker forked
    before the definition ran, and resolves to nothing under spawn).
    Raises :class:`UnpicklableTaskError` (with the offending object named)
    when neither pickle nor the code-object fallback can carry it.
    """
    if not (isinstance(fn, types.FunctionType) and fn.__module__ == "__main__"):
        try:
            return (_PICKLE, pickle.dumps(fn))
        except Exception:
            pass
    import functools

    if isinstance(fn, functools.partial):
        try:
            return (
                _PARTIAL,
                dumps_fn(fn.func),
                tuple(_dumps_cell(a) for a in fn.args),
                tuple((k, _dumps_cell(v)) for k, v in fn.keywords.items()),
            )
        except UnpicklableTaskError:
            raise
        except Exception as exc:
            raise UnpicklableTaskError(
                f"cannot serialize partial arguments of {fn!r} for a worker "
                f"process: {exc}"
            ) from exc
    if not isinstance(fn, types.FunctionType):
        # bound methods of stateful objects, callables holding locks/pools…
        raise UnpicklableTaskError(
            f"cannot serialize task body {fn!r} for a worker process — it is "
            "not a plain function and does not pickle. Run it with "
            'affinity="local", or restructure it as a module-level function.'
        )
    seen = getattr(_dump_guard, "seen", None)
    if seen is None:
        seen = _dump_guard.seen = set()
    if id(fn) in seen:
        # a closure cell containing the function itself (recursive inner
        # def): by-value capture cannot tie that knot — fail fast and
        # clearly instead of burning the stack
        raise UnpicklableTaskError(
            f"{fn.__qualname__!r} is a self-referential closure (recursive "
            "inner function); define it at module level or run the task "
            'with affinity="local".'
        )
    seen.add(id(fn))
    try:
        try:
            code = marshal.dumps(fn.__code__)
            defaults = (
                tuple(_dumps_cell(d) for d in fn.__defaults__)
                if fn.__defaults__
                else None
            )
            cells = (
                tuple(_dumps_cell(c.cell_contents) for c in fn.__closure__)
                if fn.__closure__
                else None
            )
        except UnpicklableTaskError:
            raise
        except Exception as exc:
            raise UnpicklableTaskError(
                f"cannot serialize task body {fn.__qualname__!r} for a worker "
                f"process — a captured value does not pickle: {exc}. Run it "
                'with affinity="local", or pass the value along a dataflow '
                "edge."
            ) from exc
        # Ship the globals the body actually reads, by value, so they
        # resolve to their *submission-time* state in the worker (the module
        # dict a forked worker inherited is a snapshot from pool start-up).
        # Names that refuse to pickle — including the function itself, via
        # the seen-set (a recursive module-level lambda) — are left to the
        # worker's module dict: best effort.
        shipped: list = []
        fg = fn.__globals__
        for gname in _referenced_globals(fn.__code__):
            if gname in fg and id(fg[gname]) not in seen:
                try:
                    shipped.append((gname, _dumps_cell(fg[gname])))
                except Exception:
                    pass  # fall back to the worker's module dict for this name
    finally:
        seen.discard(id(fn))
    return (_CODE, code, fn.__module__, fn.__name__, defaults, cells, tuple(shipped))


def _module_globals(module: str) -> dict:
    """Globals of the body's defining module, in the worker.

    Under ``fork`` the module object (including ``__main__`` and pytest
    test modules) is already in ``sys.modules``; under ``spawn`` it must
    be importable by name.
    """
    mod = sys.modules.get(module)
    if mod is None:
        try:
            mod = importlib.import_module(module)
        except Exception:
            return {"__builtins__": __builtins__, "__name__": module}
    return mod.__dict__


def _loads_cell(wire: Any, arena: Any = None) -> Any:
    tag = wire[0]
    if tag == _PICKLE:
        return pickle.loads(wire[1])
    if tag == _MODULE:
        return importlib.import_module(wire[1])
    return loads_fn(wire, arena)


def loads_fn(wire: tuple, arena: Any = None) -> Any:
    """Rebuild a callable from :func:`dumps_fn` output."""
    tag = wire[0]
    if tag == _PICKLE:
        return pickle.loads(wire[1])
    if tag == _PARTIAL:
        import functools

        _t, fn_w, args_w, kwargs_w = wire
        return functools.partial(
            loads_fn(fn_w, arena),
            *[_loads_cell(a, arena) for a in args_w],
            **{k: _loads_cell(v, arena) for k, v in kwargs_w},
        )
    _t, code, module, name, defaults, cells, shipped = wire
    # fresh globals per function: the worker's module dict as fallback,
    # shipped submission-time bindings overlaid (and body-side global
    # writes isolated — remote bodies are snapshots, DESIGN.md §11)
    g = dict(_module_globals(module))
    g.setdefault("__builtins__", __builtins__)
    for gname, cell in shipped:
        g[gname] = _loads_cell(cell, arena)
    fn = types.FunctionType(
        marshal.loads(code),
        g,
        name,
        tuple(_loads_cell(d) for d in defaults) if defaults is not None else None,
        tuple(types.CellType(_loads_cell(c)) for c in cells)
        if cells is not None
        else None,
    )
    return fn


# -- edge values ------------------------------------------------------------


def _as_shippable_array(value: Any) -> Optional[Any]:
    """Return a numpy view/copy when ``value`` is a numpy or jax array,
    else None. jax arrays are pulled to host — a device buffer cannot
    cross an address-space boundary, its bytes can."""
    import numpy as np

    if isinstance(value, np.ndarray):
        return value
    if type(value).__module__.split(".")[0] in ("jax", "jaxlib"):
        try:
            return np.asarray(value)
        except Exception:
            return None
    return None


def dumps_value(value: Any, arena: Any = None, _depth: int = 2) -> tuple:
    """Wire one edge value. Arrays at/above the arena threshold travel as
    shared-memory descriptors — including arrays nested one or two levels
    inside small dicts/lists/tuples (the batch-dict idiom), which are
    decomposed element-wise; everything else as pickle (callables via the
    function wire)."""
    if arena is not None:
        arr = _as_shippable_array(value)
        if arr is not None and arr.nbytes >= arena.threshold:
            return (_SHM, arena.put(arr))
        if _depth > 0 and _contains_arena_array(value, arena, _depth):
            if isinstance(value, dict):
                keys = list(value.keys())
                wires = _dumps_many(value.values(), arena, _depth - 1)
                return (_DICT, tuple(zip(keys, wires)))
            if isinstance(value, (list, tuple)):
                tag = _LIST if isinstance(value, list) else _TUPLE
                return (tag, tuple(_dumps_many(value, arena, _depth - 1)))
    try:
        return (_PICKLE, pickle.dumps(value))
    except Exception:
        if callable(value):
            return dumps_fn(value)
        raise


def _dumps_many(values: Any, arena: Any, depth: int) -> list:
    """Wire a sequence of values; on failure, recycle the arena blocks of
    the elements already wired — a half-built pack must not strand pooled
    segments outside the freelist (they would leak until pool close)."""
    out: list = []
    try:
        for v in values:
            out.append(dumps_value(v, arena, depth))
    except Exception:
        if arena is not None:
            for w in out:
                for ref in shm_refs(w):
                    arena.recycle(ref)
        raise
    return out


def _contains_arena_array(value: Any, arena: Any, depth: int) -> bool:
    """Shallow scan: does this small container hold an arena-sized array?

    Bounded by ``depth`` (how far ``dumps_value`` would decompose), so a
    self-referential container falls through to pickle — which handles
    cycles — instead of recursing here."""
    if depth <= 0:
        return False
    if isinstance(value, dict):
        items: Any = value.values()
    elif isinstance(value, (list, tuple)):
        items = value
    else:
        return False
    if len(value) > _CONTAINER_SCAN_MAX:
        return False
    for v in items:
        arr = _as_shippable_array(v)
        if arr is not None and arr.nbytes >= arena.threshold:
            return True
        if _contains_arena_array(v, arena, depth - 1):
            return True
    return False


def loads_value(wire: tuple, arena: Any = None) -> Any:
    tag = wire[0]
    if tag == _PICKLE:
        return pickle.loads(wire[1])
    if tag == _SHM:
        return arena.get(wire[1])
    if tag == _TUPLE:
        return tuple(loads_value(w, arena) for w in wire[1])
    if tag == _LIST:
        return [loads_value(w, arena) for w in wire[1]]
    if tag == _DICT:
        return {k: loads_value(w, arena) for k, w in wire[1]}
    return loads_fn(wire, arena)


def dumps_args(args: tuple, arena: Any = None) -> tuple:
    """Wire an argument pack (the task's dataflow inputs, in edge order).

    Cleanup contract: if any argument fails to serialize, arena blocks
    already allocated for earlier arguments are recycled before the
    exception propagates — the caller never sees a partial pack.
    """
    return (_TUPLE, tuple(_dumps_many(args, arena, 2)))


def loads_args(wire: tuple, arena: Any = None) -> tuple:
    return loads_value(wire, arena)


def shm_refs(wire: tuple) -> list:
    """The :class:`~repro.dist.shm_arena.ArrayRef` descriptors anywhere in
    a value/argument wire (containers included) — what the dispatcher must
    recycle once the job replies."""
    tag = wire[0]
    if tag == _SHM:
        return [wire[1]]
    if tag in (_TUPLE, _LIST):
        return [r for w in wire[1] for r in shm_refs(w)]
    if tag == _DICT:
        return [r for _k, w in wire[1] for r in shm_refs(w)]
    return []


# -- exceptions -------------------------------------------------------------


def dumps_exception(exc: BaseException) -> bytes:
    """Pickle a worker-side exception; unpicklable ones degrade to a
    ``RuntimeError`` carrying the repr (the traceback text survives in the
    message, the object graph does not)."""
    try:
        return pickle.dumps(exc)
    except Exception:
        import traceback

        return pickle.dumps(
            RuntimeError(
                "worker-side exception (unpicklable): "
                + "".join(traceback.format_exception_only(type(exc), exc)).strip()
            )
        )


def loads_exception(data: bytes) -> BaseException:
    return pickle.loads(data)
