"""Socket-worker entry point for the multi-host backend (DESIGN.md §16).

The socket transport keeps the §9/§10/§12 scheduler wholly in the parent
(exactly like the §11 process backend) and ships task *bodies* to worker
processes connected over TCP — same host or remote. This module is the
worker side: a plain loop over one duplex socket to its dispatcher thread
in the parent, plus the pieces both ends share (framing, handshake
constants) and two launchers:

* ``python -m repro.dist.remote_worker --connect host:port [--workers N]``
  — join a listening :class:`~repro.dist.socket_pool.SocketPool` from any
  machine that can import this package;
* :func:`spawn_workers` — fork-and-connect N local workers (what
  ``SocketPool`` uses for single-host runs and tests).

**Framing.** Every message is one length-prefixed frame: a 4-byte
big-endian payload length followed by a pickled payload
(:class:`FramedConn`). Frames on one socket are strictly ordered, which is
what lets the per-connection transfer cache
(:class:`~repro.dist.shm_arena.TransferCache`) mark an array digest as
peer-resident the moment the frame carrying its bytes is queued.

**Handshake.** Authentication comes first, and it runs on *raw* frames —
no pickle touches bytes from an unauthenticated peer (unpickling
attacker data is arbitrary code execution). Both directions prove
knowledge of the shared ``authkey`` with an HMAC-SHA256
challenge/response, the same shape as ``multiprocessing.connection``::

    parent -> worker   CHALLENGE || 32 random bytes          (raw frame)
    worker -> parent   HMAC-SHA256(authkey, nonce)           (raw frame)
    parent -> worker   WELCOME  (or FAILURE: connection dropped)
    worker -> parent   CHALLENGE || 32 random bytes          (roles swap:
    parent -> worker   HMAC-SHA256(authkey, nonce)            the worker
    worker -> parent   WELCOME                                authenticates
                                                              the parent too)

Only then does the pickled hello/ack exchange run::

    worker -> parent   {"magic": MAGIC, "version": PROTOCOL_VERSION,
                        "caps": {pid, host, nonce?, cpu_count, python}}
    parent -> worker   {"ok": True, "version": ..., "threshold": ...,
                        "heartbeat_s": ...}          # or {"ok": False, ...}

A peer that fails the challenge (or sends anything else first) is
dropped before any ``pickle.loads``; a version mismatch between
*authenticated* ends is rejected before the connection ever reaches a
scheduler slot. ``caps["nonce"]`` echoes the per-spawn token
:func:`spawn_workers` hands each local child, which is how the pool
binds a connection to the right ``Process`` (pids can collide across
hosts; nonces cannot).

**Trust model.** The authkey is a bearer secret: anyone holding it can
run arbitrary code on both ends (that is what a task body *is*), so it
must travel out of band over a trusted channel — an env var on the
worker hosts, a mode-0600 file — never on a command line.
``SocketPool`` generates a random key per pool when bound to loopback
and refuses to bind a non-loopback interface without an explicit one.
The transport authenticates but does not encrypt: task bodies and
results cross in cleartext, so run fleets on trusted networks (or
tunnel the port).

**Job protocol** (one in-flight job per worker — the dispatcher thread
blocks on the reply, heartbeats interleave)::

    parent -> worker   ("job", job_id, fn_wire, args_wire)   run this body
    parent -> worker   ("bye",)                               shut down
    worker -> parent   ("res", job_id, True,  result_wire)    body returned
    worker -> parent   ("res", job_id, False, exception_bytes) body raised
    worker -> parent   ("hb",)                                 liveness pulse

``fn_wire``/``args_wire``/``result_wire`` are ``repro.dist.wire`` payloads;
arrays at/above the threshold ride the content-hashed transfer cache
instead of being re-pickled into every frame.

A worker catches *everything* a body raises — including ``SystemExit`` /
``KeyboardInterrupt`` — and reports it as a task failure; only socket loss
(parent gone) or the shutdown sentinel ends the loop. A worker that dies
anyway (``os._exit``, OOM kill, a severed link) surfaces in the parent as
:class:`~repro.dist.process_pool.WorkerDiedError` on the in-flight task,
never as a hang: the heartbeat thread keeps pulsing even while a body
runs, so a silent peer is indistinguishable from a dead one only until
the liveness window expires.
"""
from __future__ import annotations

import argparse
import hashlib
import hmac
import os
import pickle
import secrets
import select
import socket
import struct
import sys
import threading
from typing import Any, Optional

from .shm_arena import DEFAULT_THRESHOLD, TransferCache
from .wire import dumps_exception, dumps_value, loads_args, loads_fn

__all__ = [
    "MAGIC",
    "PROTOCOL_VERSION",
    "AUTHKEY_ENV",
    "AuthenticationError",
    "FramedConn",
    "answer_challenge",
    "deliver_challenge",
    "worker_caps",
    "run_worker",
    "spawn_workers",
]

MAGIC = "repro-dist"
PROTOCOL_VERSION = 2  # v2: mandatory mutual HMAC auth before any pickle
DEFAULT_HEARTBEAT_S = 0.25

#: env var the ``remote_worker`` CLI reads the authkey from (hex-encoded)
AUTHKEY_ENV = "REPRO_DIST_AUTHKEY"

_HDR = struct.Struct("!I")

# auth-handshake raw-frame markers (never pickled, bounded length)
_CHALLENGE = b"#REPRO#CHALLENGE#"
_WELCOME = b"#REPRO#WELCOME#"
_FAILURE = b"#REPRO#FAILURE#"
_AUTH_NONCE_LEN = 32
_AUTH_MAX_FRAME = 128  # challenge/digest/verdict all fit well under this


class AuthenticationError(ConnectionError):
    """The peer failed (or never attempted) the authkey challenge."""


class FramedConn:
    """Length-prefixed pickle frames over one TCP socket.

    ``send`` is thread-safe (the worker's heartbeat thread shares the
    socket with its job loop); ``recv`` is single-reader by contract —
    exactly one thread reads a connection at a time (the §16 dispatcher
    holds the slot's I/O lock, the idle monitor only reads when it can
    take that lock). A ``recv`` that times out mid-frame leaves the
    stream desynchronized, which is fine: a timeout is a liveness verdict
    and the connection is discarded, never reused.
    """

    def __init__(self, sock: socket.socket) -> None:
        try:
            # frames are small and latency-bound: defeat Nagle batching
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass  # non-TCP transport (AF_UNIX socketpair in tests)
        self._sock = sock
        self._send_lock = threading.Lock()

    def send(self, obj: Any) -> None:
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        self.send_bytes(payload)

    def send_bytes(self, payload: bytes) -> None:
        """One raw frame (no pickling) — what the auth handshake rides."""
        with self._send_lock:
            self._sock.sendall(_HDR.pack(len(payload)) + payload)

    def _read_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise EOFError("peer closed the connection")
            buf += chunk
        return bytes(buf)

    def recv_bytes(
        self, timeout: Optional[float] = None, max_len: Optional[int] = None
    ) -> bytes:
        """Next frame's raw payload, without unpickling. ``max_len`` caps
        the advertised length (pre-auth frames must be tiny: an attacker
        header must not be able to command a huge allocation).

        A timeout only bounds *this* read: the socket is restored to
        blocking before returning, so a later ``send`` of a large frame
        is never clipped by a stale liveness window.
        """
        self._sock.settimeout(timeout)
        try:
            (length,) = _HDR.unpack(self._read_exact(_HDR.size))
            if max_len is not None and length > max_len:
                raise AuthenticationError(
                    f"pre-auth frame of {length} bytes exceeds the "
                    f"{max_len}-byte handshake cap"
                )
            return self._read_exact(length)
        finally:
            try:
                self._sock.settimeout(None)
            except OSError:  # pragma: no cover - racing close
                pass

    def recv(self, timeout: Optional[float] = None) -> Any:
        """Next frame's payload. Raises ``EOFError`` on orderly close,
        ``TimeoutError`` past ``timeout`` (the §16 liveness window) and
        ``OSError`` on a severed link. Only call on an *authenticated*
        connection — unpickling untrusted bytes executes them."""
        return pickle.loads(self.recv_bytes(timeout))

    def poll(self) -> bool:
        """True when a frame (or EOF) is ready to read without blocking."""
        r, _w, _x = select.select([self._sock], [], [], 0)
        return bool(r)

    def fileno(self) -> int:
        return self._sock.fileno()

    def kill(self) -> None:
        """Sever the link abruptly (both directions) — the chaos harness's
        and the §16 watchdog's connection-loss primitive."""
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.close()

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


def _coerce_authkey(authkey: Any) -> bytes:
    if isinstance(authkey, str):
        authkey = authkey.encode("utf-8")
    if not isinstance(authkey, (bytes, bytearray)) or not authkey:
        raise ValueError("authkey must be a non-empty bytes (or str) secret")
    return bytes(authkey)


def deliver_challenge(
    conn: FramedConn, authkey: bytes, *, timeout: float = 5.0
) -> None:
    """Challenge the peer to prove it holds ``authkey`` (raw frames only —
    this runs *before* any pickling trust is extended). Raises
    :class:`AuthenticationError` on a wrong or missing digest."""
    authkey = _coerce_authkey(authkey)
    nonce = secrets.token_bytes(_AUTH_NONCE_LEN)
    conn.send_bytes(_CHALLENGE + nonce)
    response = conn.recv_bytes(timeout=timeout, max_len=_AUTH_MAX_FRAME)
    expected = hmac.new(authkey, nonce, hashlib.sha256).digest()
    if not hmac.compare_digest(response, expected):
        try:
            conn.send_bytes(_FAILURE)
        except OSError:
            pass
        raise AuthenticationError("peer failed the authkey challenge")
    conn.send_bytes(_WELCOME)


def answer_challenge(
    conn: FramedConn, authkey: bytes, *, timeout: float = 5.0
) -> None:
    """Answer the peer's authkey challenge (raw frames only). Raises
    :class:`AuthenticationError` if the peer never sends a well-formed
    challenge or rejects our digest."""
    authkey = _coerce_authkey(authkey)
    msg = conn.recv_bytes(timeout=timeout, max_len=_AUTH_MAX_FRAME)
    if not msg.startswith(_CHALLENGE) or len(msg) != len(_CHALLENGE) + _AUTH_NONCE_LEN:
        raise AuthenticationError("peer did not open with an authkey challenge")
    nonce = msg[len(_CHALLENGE):]
    conn.send_bytes(hmac.new(authkey, nonce, hashlib.sha256).digest())
    verdict = conn.recv_bytes(timeout=timeout, max_len=_AUTH_MAX_FRAME)
    if verdict != _WELCOME:
        raise AuthenticationError("authkey rejected by peer")


def worker_caps() -> dict:
    """This host's capability record, sent in the handshake hello."""
    return {
        "pid": os.getpid(),
        "host": socket.gethostname(),
        "cpu_count": os.cpu_count(),
        "python": tuple(sys.version_info[:3]),
    }


def run_worker(
    host: str,
    port: int,
    *,
    authkey: bytes,
    connect_timeout: float = 20.0,
    spawn_nonce: Optional[str] = None,
) -> int:
    """Connect to a listening ``SocketPool`` and serve jobs until the
    shutdown sentinel or connection loss. Returns a process exit code
    (0 = orderly shutdown, 1 = authentication or handshake rejected).

    ``authkey`` is the pool's shared secret (``SocketPool.authkey``);
    the mutual challenge runs before any pickled frame in either
    direction, so a rogue listener on the port cannot feed this process
    bytes to unpickle. ``spawn_nonce`` is echoed in the hello caps so
    the parent can bind this connection to the ``Process`` it spawned
    (:func:`spawn_workers` sets it; remote workers leave it unset).
    """
    authkey = _coerce_authkey(authkey)
    sock = socket.create_connection((host, port), timeout=connect_timeout)
    sock.settimeout(None)  # create_connection leaves its timeout armed
    conn = FramedConn(sock)
    try:
        # answer the parent's challenge, then challenge it back — only a
        # peer that proved it holds the key may send us anything pickled
        answer_challenge(conn, authkey, timeout=connect_timeout)
        deliver_challenge(conn, authkey, timeout=connect_timeout)
    except (AuthenticationError, EOFError, OSError, TimeoutError):
        conn.close()
        return 1
    caps = worker_caps()
    if spawn_nonce is not None:
        caps["nonce"] = spawn_nonce
    conn.send({"magic": MAGIC, "version": PROTOCOL_VERSION, "caps": caps})
    try:
        ack = conn.recv(timeout=connect_timeout)
    except (EOFError, OSError, TimeoutError):
        conn.close()
        return 1
    if not (isinstance(ack, dict) and ack.get("ok")):
        conn.close()
        return 1
    cache = TransferCache(ack.get("threshold", DEFAULT_THRESHOLD))
    heartbeat_s = ack.get("heartbeat_s", DEFAULT_HEARTBEAT_S)

    stop = threading.Event()

    def _pulse() -> None:
        # keeps pulsing while a body runs, so the parent can tell "slow
        # body" from "dead worker" — the §16 liveness signal
        while not stop.wait(heartbeat_s):
            try:
                conn.send(("hb",))
            except OSError:
                return

    threading.Thread(target=_pulse, name="repro-sock-hb", daemon=True).start()
    try:
        while True:
            try:
                msg = conn.recv(timeout=None)
            except (EOFError, OSError):  # parent died or closed the link
                return 0
            if msg is None or msg[0] == "bye":  # orderly shutdown
                return 0
            _kind, job_id, fn_wire, args_wire = msg
            try:
                fn = loads_fn(fn_wire, cache)
                args = loads_args(args_wire, cache)
                result = fn(*args)
                reply = ("res", job_id, True, dumps_value(result, cache))
            except BaseException as exc:  # noqa: BLE001 - body verdicts travel home
                reply = ("res", job_id, False, dumps_exception(exc))
            try:
                conn.send(reply)
            except OSError:  # parent went away mid-reply
                return 0
    finally:
        stop.set()
        cache.close()
        conn.close()


def spawn_workers(
    n: int,
    address: tuple,
    *,
    authkey: bytes,
    mp_context: Optional[str] = None,
    name: str = "repro-sockworker",
) -> list:
    """Fork-and-connect ``n`` local worker processes against ``address``
    (``(host, port)``) — the single-host convenience ``SocketPool`` uses.
    ``authkey`` is the pool's secret (``pool.authkey``); it crosses into
    the children in-memory (process args), never on a command line.

    ``fork`` (default where available) inherits imported modules, so
    lambdas defined anywhere resolve in the worker exactly as on the §11
    process backend; ``spawn`` requires importable bodies. Returns the
    started ``multiprocessing.Process`` objects, each carrying the
    ``spawn_nonce`` its worker echoes in the hello — the collision-proof
    token the pool binds connections to processes with (pids recycle
    and collide across hosts; nonces cannot).
    """
    import multiprocessing as mp
    import warnings

    authkey = _coerce_authkey(authkey)
    ctx_name = mp_context or ("fork" if "fork" in mp.get_all_start_methods() else "spawn")
    ctx = mp.get_context(ctx_name)
    host, port = address
    procs = []
    with warnings.catch_warnings():
        # same rationale as ProcessPool._start_worker: the worker loop
        # never touches jax post-fork
        warnings.filterwarnings("ignore", message=".*fork.*", category=RuntimeWarning)
        for i in range(n):
            nonce = secrets.token_hex(16)
            proc = ctx.Process(
                target=run_worker,
                args=(host, port),
                kwargs={"authkey": authkey, "spawn_nonce": nonce},
                name=f"{name}-{i}",
                daemon=True,
            )
            proc.spawn_nonce = nonce
            proc.start()
            procs.append(proc)
    return procs


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dist.remote_worker",
        description="Join a listening repro.dist.SocketPool as a worker host.",
    )
    ap.add_argument(
        "--connect",
        required=True,
        metavar="HOST:PORT",
        help="address the SocketPool parent is listening on",
    )
    ap.add_argument(
        "--workers",
        type=int,
        default=1,
        help="worker processes to run from this host (default 1)",
    )
    ap.add_argument(
        "--authkey-file",
        metavar="PATH",
        help="file holding the pool's raw authkey bytes (overrides "
        f"${AUTHKEY_ENV}); keys never belong on a command line",
    )
    args = ap.parse_args(argv)
    host, _, port_s = args.connect.rpartition(":")
    if not host or not port_s.isdigit():
        ap.error(f"--connect expects HOST:PORT, got {args.connect!r}")
    host, port = host.strip("[]"), int(port_s)
    if args.authkey_file:
        with open(args.authkey_file, "rb") as fh:
            authkey = fh.read().strip()
    else:
        key_hex = os.environ.get(AUTHKEY_ENV, "")
        try:
            authkey = bytes.fromhex(key_hex) if key_hex else b""
        except ValueError:
            ap.error(f"${AUTHKEY_ENV} must be the authkey hex-encoded "
                     "(pool.authkey.hex())")
    if not authkey:
        ap.error(
            "no authkey: export the pool's key via "
            f"{AUTHKEY_ENV}=<pool.authkey.hex()> or pass --authkey-file "
            "(the parent refuses unauthenticated workers)"
        )
    if args.workers == 1:
        return run_worker(host, port, authkey=authkey)
    procs = spawn_workers(args.workers, (host, port), authkey=authkey)
    code = 0
    for proc in procs:
        proc.join()
        code = max(code, proc.exitcode or 0)
        proc.close()
    return code


if __name__ == "__main__":
    sys.exit(main())
