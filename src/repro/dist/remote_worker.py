"""Socket-worker entry point for the multi-host backend (DESIGN.md §16).

The socket transport keeps the §9/§10/§12 scheduler wholly in the parent
(exactly like the §11 process backend) and ships task *bodies* to worker
processes connected over TCP — same host or remote. This module is the
worker side: a plain loop over one duplex socket to its dispatcher thread
in the parent, plus the pieces both ends share (framing, handshake
constants) and two launchers:

* ``python -m repro.dist.remote_worker --connect host:port [--workers N]``
  — join a listening :class:`~repro.dist.socket_pool.SocketPool` from any
  machine that can import this package;
* :func:`spawn_workers` — fork-and-connect N local workers (what
  ``SocketPool`` uses for single-host runs and tests).

**Framing.** Every message is one length-prefixed frame: a 4-byte
big-endian payload length followed by a pickled payload
(:class:`FramedConn`). Frames on one socket are strictly ordered, which is
what lets the per-connection transfer cache
(:class:`~repro.dist.shm_arena.TransferCache`) mark an array digest as
peer-resident the moment the frame carrying its bytes is queued.

**Handshake.** The worker speaks first::

    worker -> parent   {"magic": MAGIC, "version": PROTOCOL_VERSION,
                        "caps": {pid, host, cpu_count, python}}
    parent -> worker   {"ok": True, "version": ..., "threshold": ...,
                        "heartbeat_s": ...}          # or {"ok": False, ...}

A version mismatch (or garbage on the port) is rejected before the
connection ever reaches a scheduler slot.

**Job protocol** (one in-flight job per worker — the dispatcher thread
blocks on the reply, heartbeats interleave)::

    parent -> worker   ("job", job_id, fn_wire, args_wire)   run this body
    parent -> worker   ("bye",)                               shut down
    worker -> parent   ("res", job_id, True,  result_wire)    body returned
    worker -> parent   ("res", job_id, False, exception_bytes) body raised
    worker -> parent   ("hb",)                                 liveness pulse

``fn_wire``/``args_wire``/``result_wire`` are ``repro.dist.wire`` payloads;
arrays at/above the threshold ride the content-hashed transfer cache
instead of being re-pickled into every frame.

A worker catches *everything* a body raises — including ``SystemExit`` /
``KeyboardInterrupt`` — and reports it as a task failure; only socket loss
(parent gone) or the shutdown sentinel ends the loop. A worker that dies
anyway (``os._exit``, OOM kill, a severed link) surfaces in the parent as
:class:`~repro.dist.process_pool.WorkerDiedError` on the in-flight task,
never as a hang: the heartbeat thread keeps pulsing even while a body
runs, so a silent peer is indistinguishable from a dead one only until
the liveness window expires.
"""
from __future__ import annotations

import argparse
import os
import pickle
import select
import socket
import struct
import sys
import threading
from typing import Any, Optional

from .shm_arena import DEFAULT_THRESHOLD, TransferCache
from .wire import dumps_exception, dumps_value, loads_args, loads_fn

__all__ = [
    "MAGIC",
    "PROTOCOL_VERSION",
    "FramedConn",
    "worker_caps",
    "run_worker",
    "spawn_workers",
]

MAGIC = "repro-dist"
PROTOCOL_VERSION = 1
DEFAULT_HEARTBEAT_S = 0.25

_HDR = struct.Struct("!I")


class FramedConn:
    """Length-prefixed pickle frames over one TCP socket.

    ``send`` is thread-safe (the worker's heartbeat thread shares the
    socket with its job loop); ``recv`` is single-reader by contract —
    exactly one thread reads a connection at a time (the §16 dispatcher
    holds the slot's I/O lock, the idle monitor only reads when it can
    take that lock). A ``recv`` that times out mid-frame leaves the
    stream desynchronized, which is fine: a timeout is a liveness verdict
    and the connection is discarded, never reused.
    """

    def __init__(self, sock: socket.socket) -> None:
        try:
            # frames are small and latency-bound: defeat Nagle batching
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass  # non-TCP transport (AF_UNIX socketpair in tests)
        self._sock = sock
        self._send_lock = threading.Lock()

    def send(self, obj: Any) -> None:
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        with self._send_lock:
            self._sock.sendall(_HDR.pack(len(payload)) + payload)

    def _read_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise EOFError("peer closed the connection")
            buf += chunk
        return bytes(buf)

    def recv(self, timeout: Optional[float] = None) -> Any:
        """Next frame's payload. Raises ``EOFError`` on orderly close,
        ``TimeoutError`` past ``timeout`` (the §16 liveness window) and
        ``OSError`` on a severed link."""
        self._sock.settimeout(timeout)
        (length,) = _HDR.unpack(self._read_exact(_HDR.size))
        return pickle.loads(self._read_exact(length))

    def poll(self) -> bool:
        """True when a frame (or EOF) is ready to read without blocking."""
        r, _w, _x = select.select([self._sock], [], [], 0)
        return bool(r)

    def fileno(self) -> int:
        return self._sock.fileno()

    def kill(self) -> None:
        """Sever the link abruptly (both directions) — the chaos harness's
        and the §16 watchdog's connection-loss primitive."""
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.close()

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


def worker_caps() -> dict:
    """This host's capability record, sent in the handshake hello."""
    return {
        "pid": os.getpid(),
        "host": socket.gethostname(),
        "cpu_count": os.cpu_count(),
        "python": tuple(sys.version_info[:3]),
    }


def run_worker(
    host: str,
    port: int,
    *,
    connect_timeout: float = 20.0,
) -> int:
    """Connect to a listening ``SocketPool`` and serve jobs until the
    shutdown sentinel or connection loss. Returns a process exit code
    (0 = orderly shutdown, 1 = handshake rejected).
    """
    sock = socket.create_connection((host, port), timeout=connect_timeout)
    conn = FramedConn(sock)
    conn.send({"magic": MAGIC, "version": PROTOCOL_VERSION, "caps": worker_caps()})
    try:
        ack = conn.recv(timeout=connect_timeout)
    except (EOFError, OSError, TimeoutError):
        conn.close()
        return 1
    if not (isinstance(ack, dict) and ack.get("ok")):
        conn.close()
        return 1
    cache = TransferCache(ack.get("threshold", DEFAULT_THRESHOLD))
    heartbeat_s = ack.get("heartbeat_s", DEFAULT_HEARTBEAT_S)

    stop = threading.Event()

    def _pulse() -> None:
        # keeps pulsing while a body runs, so the parent can tell "slow
        # body" from "dead worker" — the §16 liveness signal
        while not stop.wait(heartbeat_s):
            try:
                conn.send(("hb",))
            except OSError:
                return

    threading.Thread(target=_pulse, name="repro-sock-hb", daemon=True).start()
    try:
        while True:
            try:
                msg = conn.recv(timeout=None)
            except (EOFError, OSError):  # parent died or closed the link
                return 0
            if msg is None or msg[0] == "bye":  # orderly shutdown
                return 0
            _kind, job_id, fn_wire, args_wire = msg
            try:
                fn = loads_fn(fn_wire, cache)
                args = loads_args(args_wire, cache)
                result = fn(*args)
                reply = ("res", job_id, True, dumps_value(result, cache))
            except BaseException as exc:  # noqa: BLE001 - body verdicts travel home
                reply = ("res", job_id, False, dumps_exception(exc))
            try:
                conn.send(reply)
            except OSError:  # parent went away mid-reply
                return 0
    finally:
        stop.set()
        cache.close()
        conn.close()


def spawn_workers(
    n: int,
    address: tuple,
    *,
    mp_context: Optional[str] = None,
    name: str = "repro-sockworker",
) -> list:
    """Fork-and-connect ``n`` local worker processes against ``address``
    (``(host, port)``) — the single-host convenience ``SocketPool`` uses.

    ``fork`` (default where available) inherits imported modules, so
    lambdas defined anywhere resolve in the worker exactly as on the §11
    process backend; ``spawn`` requires importable bodies. Returns the
    started ``multiprocessing.Process`` objects.
    """
    import multiprocessing as mp
    import warnings

    ctx_name = mp_context or ("fork" if "fork" in mp.get_all_start_methods() else "spawn")
    ctx = mp.get_context(ctx_name)
    host, port = address
    procs = []
    with warnings.catch_warnings():
        # same rationale as ProcessPool._start_worker: the worker loop
        # never touches jax post-fork
        warnings.filterwarnings("ignore", message=".*fork.*", category=RuntimeWarning)
        for i in range(n):
            proc = ctx.Process(
                target=run_worker, args=(host, port), name=f"{name}-{i}", daemon=True
            )
            proc.start()
            procs.append(proc)
    return procs


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dist.remote_worker",
        description="Join a listening repro.dist.SocketPool as a worker host.",
    )
    ap.add_argument(
        "--connect",
        required=True,
        metavar="HOST:PORT",
        help="address the SocketPool parent is listening on",
    )
    ap.add_argument(
        "--workers",
        type=int,
        default=1,
        help="worker processes to run from this host (default 1)",
    )
    args = ap.parse_args(argv)
    host, _, port_s = args.connect.rpartition(":")
    if not host or not port_s.isdigit():
        ap.error(f"--connect expects HOST:PORT, got {args.connect!r}")
    host, port = host.strip("[]"), int(port_s)
    if args.workers == 1:
        return run_worker(host, port)
    procs = spawn_workers(args.workers, (host, port))
    code = 0
    for proc in procs:
        proc.join()
        code = max(code, proc.exitcode or 0)
        proc.close()
    return code


if __name__ == "__main__":
    sys.exit(main())
