"""repro.dist — multi-process execution backend (DESIGN.md §11).

The paper's scheduler stays in one address space; this package lets task
*bodies* escape the GIL into worker processes while the parent keeps every
scheduling decision:

* :class:`ProcessPool` — a :class:`~repro.core.ThreadPool` whose
  dispatcher threads proxy wired bodies to paired worker processes
  (``Executor(backend="process")`` is the usual front door);
* :class:`ShmArena` / :class:`ArrayRef` — the shared-memory data plane for
  large numpy/jax edge values;
* :class:`UnpicklableTaskError` — submit-time verdict for a body that
  cannot ship; :func:`picklability_error` — the same verdict as a
  non-raising probe (the ``repro.analysis`` linter's static check);
  :class:`WorkerDiedError` — a worker death surfaced as a task failure
  (never a hang).
"""
from .process_pool import ProcessPool, WorkerDiedError
from .shm_arena import DEFAULT_THRESHOLD, ArrayRef, ShmArena
from .wire import UnpicklableTaskError, picklability_error

__all__ = [
    "ProcessPool",
    "WorkerDiedError",
    "ShmArena",
    "ArrayRef",
    "DEFAULT_THRESHOLD",
    "UnpicklableTaskError",
    "picklability_error",
]
