"""repro.dist — multi-process and multi-host execution backends
(DESIGN.md §11, §16).

The paper's scheduler stays in one address space; this package lets task
*bodies* escape the GIL into worker processes — on this host or across a
fleet — while the parent keeps every scheduling decision:

* :class:`ProcessPool` — a :class:`~repro.core.ThreadPool` whose
  dispatcher threads proxy wired bodies to paired worker processes over
  pipes (``Executor(backend="process")`` is the usual front door);
* :class:`SocketPool` — the same scheduler-in-parent shape over TCP:
  workers connect (locally forked, or from other hosts via ``python -m
  repro.dist.remote_worker --connect host:port``) and bodies ship as
  length-prefixed frames (``Executor(backend="socket")``);
* :class:`ShmArena` / :class:`ArrayRef` — the shared-memory data plane
  for large numpy/jax edge values on the single-host backend;
* :class:`TransferCache` / :class:`CacheRef` — its cross-host
  counterpart: per-connection content-hashed array transfer (bytes cross
  a connection once, repeats ship as digests);
* :func:`spawn_workers` — fork-and-connect local socket workers (needs
  the pool's :attr:`~SocketPool.authkey`: every connection passes a
  mutual HMAC challenge before anything is unpickled);
  :class:`AuthenticationError` — a peer that failed that challenge;
* :class:`UnpicklableTaskError` — submit-time verdict for a body that
  cannot ship; :func:`picklability_error` — the same verdict as a
  non-raising probe (the ``repro.analysis`` linter's static check);
  :class:`WorkerDiedError` — a worker death surfaced as a task failure
  (never a hang), on either backend.
"""
from .process_pool import ProcessPool, WorkerDiedError
from .remote_worker import AuthenticationError, spawn_workers
from .shm_arena import (
    DEFAULT_THRESHOLD,
    ArrayRef,
    CacheRef,
    ShmArena,
    TransferCache,
)
from .socket_pool import SocketPool
from .wire import UnpicklableTaskError, picklability_error

__all__ = [
    "ProcessPool",
    "SocketPool",
    "WorkerDiedError",
    "ShmArena",
    "ArrayRef",
    "TransferCache",
    "CacheRef",
    "DEFAULT_THRESHOLD",
    "spawn_workers",
    "AuthenticationError",
    "UnpicklableTaskError",
    "picklability_error",
]
