"""Socket-pool execution backend: scheduler in the parent, bodies on
TCP-connected worker processes — same host or a fleet (DESIGN.md §16).

:class:`SocketPool` is the §11 process backend with the pipe swapped for
a socket. It **is** a :class:`~repro.core.ThreadPool` — countdown tokens,
condition branches, subflow splices, counted completion, priorities,
observers and replay all run unchanged in the parent — whose dispatcher
threads proxy wired bodies over one duplex TCP connection per worker
slot, using the exact same two seams (``_wire_tasks`` / ``_offload``)
and the exact same placement rule as :class:`~repro.dist.ProcessPool`.
The transport details (framing, handshake, job protocol, heartbeats)
live in :mod:`repro.dist.remote_worker`, which both ends share.

Workers join in two ways:

* ``spawn_local=True`` (default): the pool forks ``num_workers`` local
  workers that connect back — a drop-in multi-process backend with a
  socket transport (what the conformance suite runs);
* ``spawn_local=False``: the pool just listens on ``(host, port)`` and
  workers anywhere run ``python -m repro.dist.remote_worker --connect
  host:port``; :attr:`SocketPool.address` is the bound address to hand
  out. Slots fill in connection order; a task dispatched to an empty
  slot waits ``connect_timeout`` for a worker to arrive.

Every connection — local or remote — must pass a mutual HMAC-SHA256
challenge over raw frames before the first pickled byte is read in
either direction (task bodies are code, so the wire protocol is
code-execution-by-design; the :attr:`authkey` is the admission control).
Loopback pools key themselves; a non-loopback bind demands an explicit
``authkey=``. The trust model is documented in
:mod:`repro.dist.remote_worker`.

Fault model (DESIGN.md §14 extended across hosts): every worker loss —
socket EOF, a severed link, a heartbeat lapse — fails *that task* with
:class:`~repro.dist.process_pool.WorkerDiedError`, the slot is respawned
(local) or re-opened for the next connecting worker (remote), and the
failure takes the normal §8 route. ``started=False`` (the job never left
the parent) is always safe to retry and the implicit transport-loss
policy resubmits it once; ``started=True`` (the body may have partially
run) is at-most-once unless the task declared ``idempotent=True``.
Workers pulse a heartbeat frame every ``heartbeat_s`` even while a body
runs, so a silent peer is declared dead after ``liveness_s`` without a
frame — a hang can never outlive the liveness window. ``timeout=`` tasks
get the §14 hard watchdog: local workers are SIGKILLed, remote workers
have their connection severed, and the task fails with
:class:`~repro.core.TaskTimeoutError` either way.

Large arrays ride the per-connection content-hashed
:class:`~repro.dist.shm_arena.TransferCache` instead of the (single-host)
shared-memory arena: a given array's bytes cross each connection once,
repeats ship as 16-byte digests. Each (re)connection gets a fresh cache
on both ends, so a respawn can never resolve a digest its peer lost.
"""
from __future__ import annotations

import ipaddress
import os
import secrets
import socket
import threading
import time
from typing import Any, Optional, Sequence

from repro.core.pool import ThreadPool
from repro.core.task import Task, TaskTimeoutError

from .process_pool import _TRANSPORT_RETRY, ProcessPool, WorkerDiedError, _WireError
from .remote_worker import (
    DEFAULT_HEARTBEAT_S,
    MAGIC,
    PROTOCOL_VERSION,
    AuthenticationError,
    FramedConn,
    answer_challenge,
    deliver_challenge,
    spawn_workers,
)
from .shm_arena import DEFAULT_THRESHOLD, TransferCache
from .wire import UnpicklableTaskError, dumps_args, loads_exception, loads_value

__all__ = ["SocketPool"]

# a slot claimed by a half-done handshake: reserved, but not dispatchable
_PENDING = object()


def _is_loopback(host: str) -> bool:
    if host in ("localhost", ""):
        return True
    try:
        return ipaddress.ip_address(host).is_loopback
    except ValueError:
        return False


class SocketPool(ThreadPool):
    """Work-stealing scheduler whose task bodies run on socket-connected
    worker processes (same host or remote — DESIGN.md §16).

    Drop-in for :class:`~repro.core.ThreadPool` (same submit / wait_idle /
    observer / stats surface — ``Executor(backend="socket")`` is the usual
    front door). One worker connection and one dispatcher thread per slot;
    jobs cross as length-prefixed pickle frames, large arrays ride the
    per-connection transfer cache.

    Parameters
    ----------
    num_workers:
        Worker-slot count (default ``os.cpu_count()``); also the
        dispatcher-thread count in the parent. ``workers=`` is an alias
        (``Executor(backend="socket", workers=4)`` reads naturally).
    host, port:
        Listening address. The default ``("127.0.0.1", 0)`` binds an
        ephemeral localhost port — read :attr:`address` for the actual
        one. Bind ``"0.0.0.0"`` to accept workers from other hosts —
        this *requires* an explicit ``authkey``.
    authkey:
        Shared secret gating every connection: both ends must answer an
        HMAC-SHA256 challenge over raw frames before the first pickled
        byte is accepted (unpickling unauthenticated network data would
        be remote code execution). On a loopback bind the default is a
        fresh random key per pool — read :attr:`authkey` and hand it to
        out-of-band workers (``REPRO_DIST_AUTHKEY=<hex>`` for the CLI).
        A non-loopback bind refuses to start without an explicit key.
        The transport authenticates but does not encrypt; see the trust
        model in :mod:`repro.dist.remote_worker`.
    spawn_local:
        Fork-and-connect ``num_workers`` local workers (default). With
        ``False`` the pool only listens; start workers yourself with
        ``python -m repro.dist.remote_worker --connect host:port``.
    arena_threshold:
        Minimum array size (bytes) to route through the content-hashed
        transfer cache instead of inline pickling
        (``repro.dist.shm_arena.DEFAULT_THRESHOLD`` = 32 KiB).
    heartbeat_s:
        Worker liveness-pulse period (seconds).
    liveness_s:
        Declare a worker dead after this long without any frame
        (default ``max(2.0, 10 * heartbeat_s)``). Must comfortably
        exceed ``heartbeat_s``.
    connect_timeout:
        How long a dispatcher waits for a worker to occupy its slot
        (startup wait with ``spawn_local=True`` uses it too).
    mp_context:
        ``"fork"`` / ``"spawn"`` for locally spawned workers (same
        trade-off as :class:`~repro.dist.ProcessPool`).
    name, observers, deque_cls:
        Forwarded to :class:`~repro.core.ThreadPool`.

    Same pool surface, bodies across a socket::

        >>> from repro.dist import SocketPool
        >>> with SocketPool(2) as pool:
        ...     fut = pool.submit_future(lambda: sum(i * i for i in range(100)))
        ...     fut.result(30)
        328350
    """

    #: bound listening address ``(host, port)`` — hand this to remote
    #: workers (per-instance; the ephemeral default port is resolved at
    #: construction)
    address: tuple = ()

    #: the pool's shared auth secret (bytes) — treat like a password;
    #: remote workers need it (``REPRO_DIST_AUTHKEY=<authkey.hex()>``)
    authkey: bytes = b""

    def __init__(
        self,
        num_workers: Optional[int] = None,
        *,
        workers: Optional[int] = None,
        host: str = "127.0.0.1",
        port: int = 0,
        authkey: Optional[bytes] = None,
        spawn_local: bool = True,
        arena_threshold: int = DEFAULT_THRESHOLD,
        heartbeat_s: float = DEFAULT_HEARTBEAT_S,
        liveness_s: Optional[float] = None,
        connect_timeout: float = 20.0,
        mp_context: Optional[str] = None,
        name: str = "repro-sockpool",
        observers: Sequence[Any] = (),
        **pool_kwargs: Any,
    ) -> None:
        if workers is not None:
            num_workers = workers
        n = num_workers if num_workers is not None else (os.cpu_count() or 1)
        if n < 1:
            raise ValueError("num_workers must be >= 1")
        if heartbeat_s <= 0:
            raise ValueError("heartbeat_s must be > 0")
        self._n_slots = n
        self._threshold = arena_threshold
        self._hb_s = heartbeat_s
        self._liveness_s = (
            liveness_s if liveness_s is not None else max(2.0, 10.0 * heartbeat_s)
        )
        if self._liveness_s <= heartbeat_s:
            raise ValueError("liveness_s must exceed heartbeat_s")
        self._connect_timeout = connect_timeout
        self._spawn_local = spawn_local
        self._mp_context = mp_context
        self._worker_name = name
        if authkey is None:
            if not _is_loopback(host):
                raise ValueError(
                    f"binding {host!r} exposes the pool beyond this machine: "
                    "pass an explicit authkey= (a non-loopback listener "
                    "without one would let any peer on the network attempt "
                    "the handshake; see the trust model in "
                    "repro.dist.remote_worker)"
                )
            authkey = secrets.token_bytes(32)
        elif isinstance(authkey, str):
            authkey = authkey.encode("utf-8")
        if not authkey:
            raise ValueError("authkey must be non-empty")
        self.authkey: bytes = bytes(authkey)

        self._conns: list[Any] = [None] * n  # FramedConn | _PENDING | None
        self._caches: list[Any] = [None] * n  # TransferCache per live conn
        self._procs: list[Any] = [None] * n  # local Process, None for remote
        self._caps: list[Any] = [None] * n  # handshake capability records
        self._io_locks = [threading.Lock() for _ in range(n)]  # one reader per conn
        self._slot_ready = [threading.Event() for _ in range(n)]
        self._last_seen = [0.0] * n
        self._job_seq = [0] * n
        self._remote_jobs = [0] * n
        self._restarts = [0] * n
        self._worker_kills = [0] * n  # §14 hard-timeout kills
        self._hb_lapses = [0] * n  # liveness-window expiries
        self._rejected = 0  # handshakes turned away (post-auth)
        self._auth_failures = 0  # peers dropped before any unpickling
        self._pending_respawns = 0  # spawned workers replaced pre-connect
        self._empty_slot_timeouts = 0  # dispatches that found no worker
        # set when the idle monitor retires a slot's worker: the next job
        # dispatched there fails started=False exactly as ProcessPool's
        # next send into a dead pipe would — keeps the §14 failure
        # schedule deterministic no matter who discovers a death first
        self._transport_fault = [False] * n
        self._current_remote: list[Any] = [None] * n
        self._pending_procs: list[Any] = []  # spawned, not yet slot-bound
        self._proc_lock = threading.Lock()
        self._net_stop = threading.Event()

        # listener first, workers second (fork before any parent thread
        # exists — same fork-safety discipline as ProcessPool), threads last:
        # the TCP backlog parks early connections until the acceptor runs
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((host, port))
        listener.listen(n + 8)
        self._listener = listener
        self.address: tuple = listener.getsockname()[:2]
        if spawn_local:
            self._pending_procs = spawn_workers(
                n, self.address, authkey=self.authkey,
                mp_context=mp_context, name=name,
            )
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"{name}-accept", daemon=True
        )
        self._accept_thread.start()
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, name=f"{name}-monitor", daemon=True
        )
        self._monitor_thread.start()
        try:
            super().__init__(n, name=name, observers=observers, **pool_kwargs)
        except BaseException:
            self._teardown_net()
            raise
        self._wire_tasks = self._wire_graph
        self._offload = self._offload_body
        if spawn_local:
            # crisp startup failures: every forked worker must arrive
            deadline = time.monotonic() + connect_timeout
            for ev in self._slot_ready:
                if not ev.wait(max(0.0, deadline - time.monotonic())):
                    self.close()
                    raise RuntimeError(
                        f"socket pool startup: {n} local workers did not all "
                        f"connect within {connect_timeout}s"
                    )

    # -- wiring (submit-time): identical placement rule to the §11 backend ------

    _wire_graph = ProcessPool._wire_graph
    _wire_for = staticmethod(ProcessPool._wire_for)

    # -- dispatch (worker-thread side) ------------------------------------------

    def _offload_body(self, task: Task, index: int) -> None:
        """Body-execution seam bound into ``ThreadPool._execute``."""
        wire = task._wire
        if wire is None:
            task.run()
        elif type(wire) is _WireError:
            task.run(invoke=wire.raise_)
        else:
            task.run(
                invoke=lambda fn, args: self._remote_call(index, wire, args, fn, task)
            )

    def _endpoint(self, index: int) -> tuple:
        """The slot's live connection + cache, waiting ``connect_timeout``
        for a worker to arrive (remote mode fills slots in join order)."""
        deadline = time.monotonic() + self._connect_timeout
        while True:
            with self._proc_lock:
                conn, cache, proc = (
                    self._conns[index],
                    self._caches[index],
                    self._procs[index],
                )
            if isinstance(conn, FramedConn):
                return conn, cache, proc
            if self._net_stop.is_set():
                raise WorkerDiedError(
                    f"socket pool is closing; slot {index} abandoned its job",
                    started=False,
                )
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                with self._proc_lock:
                    self._empty_slot_timeouts += 1
                raise WorkerDiedError(
                    f"no worker connected to slot {index} within "
                    f"{self._connect_timeout}s",
                    started=False,
                )
            self._slot_ready[index].wait(min(remaining, 0.1))

    def _remote_call(
        self, index: int, fn_wire: tuple, args: tuple, fn: Any, task: Task
    ) -> Any:
        """Ship one job to the worker on slot ``index``, block for its
        verdict, treating heartbeat frames as liveness (not replies)."""
        with self._io_locks[index]:  # sole reader of this connection
            conn, cache, proc = self._endpoint(index)
            with self._proc_lock:
                fault, self._transport_fault[index] = (
                    self._transport_fault[index],
                    False,
                )
            if fault:
                # the idle monitor already retired a dead worker here: the
                # next job still observes the loss (ProcessPool's next
                # send into a dead pipe would), then capacity is restored
                raise WorkerDiedError(
                    f"worker on slot {index} died while idle "
                    "(connection lost between jobs)",
                    started=False,
                )
            if proc is not None and proc.exitcode is not None:
                # local worker died while idle: fail fast *before* the
                # send (TCP buffers would happily swallow it) — the job
                # never left the parent, so started=False and the
                # implicit transport-loss retry resubmits it
                self._respawn(index, conn)
                raise WorkerDiedError(
                    f"worker process on slot {index} died before accepting a job",
                    started=False,
                )
            self._job_seq[index] += 1
            job_id = self._job_seq[index]
            try:
                args_wire = dumps_args(args, cache)
            except Exception as exc:
                # §11 "any" fallback extends to edge values (thread parity);
                # affinity="remote" keeps the clear contract error
                if task.affinity == "remote":
                    raise UnpicklableTaskError(
                        f"task {task.name or fn!r} has affinity='remote' but a "
                        f"dataflow input cannot be shipped to a worker: {exc}"
                    ) from exc
                return fn(*args)
            watched = task.timeout is not None
            if watched:
                task._timed_out = False  # a prior kill may have raced the reply
                self._current_remote[index] = task
                self._timer_get().add(
                    time.monotonic() + task.timeout,
                    lambda a=task._attempt: self._hard_timeout(task, index, a),
                )
            try:
                try:
                    conn.send(("job", job_id, fn_wire, args_wire))
                except OSError:
                    self._respawn(index, conn)
                    raise WorkerDiedError(
                        f"worker on slot {index} died before accepting a job",
                        started=False,
                    ) from None
                while True:
                    try:
                        msg = conn.recv(timeout=self._liveness_s)
                    except TimeoutError:
                        # not even a heartbeat within the window: the peer
                        # is wedged or the link is half-open — declare it
                        self._hb_lapses[index] += 1
                        self._respawn(index, conn)
                        raise WorkerDiedError(
                            f"worker on slot {index} missed the "
                            f"{self._liveness_s}s liveness window while "
                            "executing a task body",
                            started=True,
                        ) from None
                    except (EOFError, OSError):
                        self._respawn(index, conn)
                        if task._timed_out:
                            raise TaskTimeoutError(
                                f"task {task.name!r} exceeded its "
                                f"{task.timeout}s timeout (worker on slot "
                                f"{index} killed)"
                            ) from None
                        raise WorkerDiedError(
                            f"worker on slot {index} died while executing "
                            "a task body",
                            started=True,
                        ) from None
                    if msg and msg[0] == "hb":
                        self._last_seen[index] = time.monotonic()
                        continue
                    break
            finally:
                if watched:
                    with self._proc_lock:  # fences the watchdog's is-check
                        self._current_remote[index] = None
        _kind, jid, ok, payload = msg
        self._last_seen[index] = time.monotonic()
        if jid != job_id:  # can only happen after a half-delivered respawn
            self._respawn(index, conn)
            raise WorkerDiedError(
                f"worker on slot {index} protocol desync (job {jid} != {job_id})"
            )
        self._remote_jobs[index] += 1
        if ok:
            return loads_value(payload, cache)
        raise loads_exception(payload)

    # -- fault tolerance (DESIGN.md §14 across hosts) ---------------------------

    def _retry_policy_for(self, task: Task, exc: BaseException) -> Any:
        """Task policy first (base rule); otherwise the implicit one-shot
        transport-loss retry — the base pool's at-most-once gate still
        blocks ``started=True`` losses for non-idempotent tasks."""
        pol = super()._retry_policy_for(task, exc)
        if pol is None and isinstance(exc, WorkerDiedError):
            return _TRANSPORT_RETRY
        return pol

    def _hard_timeout(self, task: Task, index: int, attempt: int) -> None:
        """Timer-thread callback for ``timeout=`` tasks: SIGKILL a local
        worker, sever a remote one's connection. The (task, attempt) pair
        guards against firing for an execution that no longer exists."""
        with self._proc_lock:
            if self._current_remote[index] is not task or task._attempt != attempt:
                return
            task._timed_out = True
            self._worker_kills[index] += 1
            proc, conn = self._procs[index], self._conns[index]
        if proc is not None:
            proc.kill()  # dispatcher's recv sees EOF -> TaskTimeoutError
        elif isinstance(conn, FramedConn):
            conn.kill()  # remote worker: cut the link instead

    # -- connection lifecycle ---------------------------------------------------

    def _accept_loop(self) -> None:
        """Acceptor thread: authenticate, then handshake, every connecting
        worker and bind it to a free slot (or turn it away)."""
        while not self._net_stop.is_set():
            try:
                sock, _addr = self._listener.accept()
            except OSError:  # listener closed: pool is shutting down
                return
            conn = FramedConn(sock)
            try:
                # mutual HMAC challenge over raw frames — nothing from
                # this peer is unpickled until it proves it holds the
                # authkey (pickle.loads on attacker bytes is RCE)
                deliver_challenge(conn, self.authkey, timeout=5.0)
                answer_challenge(conn, self.authkey, timeout=5.0)
            except Exception:  # wrong key, garbage, timeout, vanished peer
                self._auth_failures += 1
                conn.close()
                continue
            try:
                hello = conn.recv(timeout=5.0)
            except Exception:  # garbage frame, timeout, or a vanished peer
                conn.close()
                continue
            if not (
                isinstance(hello, dict)
                and hello.get("magic") == MAGIC
                and hello.get("version") == PROTOCOL_VERSION
            ):
                # garbage on the port, or a version-skewed worker: reject
                # before it ever reaches a scheduler slot
                self._rejected += 1
                try:
                    conn.send(
                        {"ok": False, "error": "protocol mismatch",
                         "version": PROTOCOL_VERSION}
                    )
                except OSError:
                    pass
                conn.close()
                continue
            caps = hello.get("caps") or {}
            with self._proc_lock:
                slot = next(
                    (i for i in range(self._n_slots) if self._conns[i] is None), None
                )
                if slot is not None:
                    self._conns[slot] = _PENDING  # reserve until the ack lands
            if slot is None:
                self._rejected += 1
                try:
                    conn.send({"ok": False, "error": "no free worker slot"})
                except OSError:
                    pass
                conn.close()
                continue
            try:
                conn.send(
                    {"ok": True, "version": PROTOCOL_VERSION,
                     "threshold": self._threshold, "heartbeat_s": self._hb_s}
                )
            except OSError:
                with self._proc_lock:
                    self._conns[slot] = None
                conn.close()
                continue
            # ack sent before the slot goes live: the wire order ack-then-job
            # is what the worker's handshake relies on
            with self._proc_lock:
                proc = None
                # bind by the per-spawn nonce, never by pid: pids recycle
                # and collide across hosts, and a mis-bound Process would
                # aim liveness probes and watchdog SIGKILLs at a stranger
                nonce = caps.get("nonce")
                if nonce is not None:
                    for p in self._pending_procs:
                        if getattr(p, "spawn_nonce", None) == nonce:
                            proc = p
                            self._pending_procs.remove(p)
                            break
                self._conns[slot] = conn
                self._caches[slot] = TransferCache(self._threshold)
                self._procs[slot] = proc
                self._caps[slot] = caps
                self._last_seen[slot] = time.monotonic()
            self._slot_ready[slot].set()

    def _monitor_loop(self) -> None:
        """Idle-liveness thread: drain heartbeats from slots whose
        dispatcher is not mid-job, respawn silently-dead workers so a
        loss is usually discovered *before* the next dispatch, and
        replace spawned workers that died before ever connecting."""
        while not self._net_stop.wait(self._hb_s):
            self._refill_pending()
            now = time.monotonic()
            for i in range(self._n_slots):
                io = self._io_locks[i]
                if not io.acquire(blocking=False):
                    continue  # dispatcher owns the socket; it enforces liveness
                try:
                    with self._proc_lock:
                        conn = self._conns[i]
                    if not isinstance(conn, FramedConn):
                        continue
                    try:
                        while conn.poll():
                            # poll() guarantees one readable *byte*, not a
                            # whole frame: allow the full liveness window
                            # for the rest to arrive, or WAN jitter would
                            # read as a death mid-heartbeat
                            conn.recv(timeout=self._liveness_s)
                            self._last_seen[i] = now
                    except (EOFError, OSError, TimeoutError):
                        if self._respawn(i, conn):
                            with self._proc_lock:
                                self._transport_fault[i] = True
                        continue
                    if now - self._last_seen[i] > self._liveness_s:
                        self._hb_lapses[i] += 1
                        if self._respawn(i, conn):
                            with self._proc_lock:
                                self._transport_fault[i] = True
                finally:
                    io.release()

    def _refill_pending(self) -> None:
        """Replace locally spawned workers that exited before occupying a
        slot (an import failure in the child, an OOM kill during startup):
        without this the slot would sit empty for the pool's lifetime,
        burning ``connect_timeout`` on every task routed there."""
        if not self._spawn_local:
            return
        with self._proc_lock:
            dead = [p for p in self._pending_procs if p.exitcode is not None]
            for p in dead:
                self._pending_procs.remove(p)
            empty = sum(1 for c in self._conns if c is None)
            live_pending = len(self._pending_procs)
        for p in dead:
            p.join(timeout=0.1)
            try:
                p.close()
            except Exception:
                pass
        need = min(len(dead), max(0, empty - live_pending))
        if need and not self._net_stop.is_set():
            self._pending_respawns += need
            replacement = spawn_workers(
                need, self.address, authkey=self.authkey,
                mp_context=self._mp_context, name=self._worker_name,
            )
            with self._proc_lock:
                self._pending_procs.extend(replacement)

    def _respawn(self, index: int, dead_conn: Any = None) -> bool:
        """Retire slot ``index``'s connection (and local process, if any)
        and restore capacity: fork a replacement with ``spawn_local``,
        else re-open the slot for the next connecting worker.

        ``dead_conn`` makes the call idempotent under races: the idle
        monitor and a dispatcher can both observe the same death, and
        only the first observer actually respawns (returns True).
        """
        with self._proc_lock:
            if dead_conn is not None and self._conns[index] is not dead_conn:
                return False  # another path already retired this connection
            self._restarts[index] += 1
            self._slot_ready[index].clear()
            conn, self._conns[index] = self._conns[index], None
            cache, self._caches[index] = self._caches[index], None
            proc, self._procs[index] = self._procs[index], None
            self._caps[index] = None
        if isinstance(conn, FramedConn):
            conn.kill()
        if cache is not None:
            cache.close()
        if proc is not None:
            proc.join(timeout=0.1)
            if proc.is_alive():  # link broke but the process wedged
                proc.terminate()
                proc.join(timeout=1.0)
                if proc.is_alive():
                    proc.kill()
                    proc.join(timeout=1.0)
            try:
                proc.close()  # release FDs now, not at GC (§14 regression)
            except Exception:
                pass
        if self._spawn_local and not self._net_stop.is_set():
            replacement = spawn_workers(
                1, self.address, authkey=self.authkey,
                mp_context=self._mp_context, name=self._worker_name,
            )
            with self._proc_lock:
                self._pending_procs.extend(replacement)
        return True

    # -- lifecycle / stats ------------------------------------------------------

    def stats(self) -> dict[str, int]:
        """Base pool counters plus the transport's: ``remote_jobs``
        (bodies run on workers), ``worker_restarts``, ``worker_kills``
        (§14 watchdog), ``heartbeat_lapses`` (liveness-window expiries),
        ``handshakes_rejected``, ``auth_failures`` (peers dropped before
        any unpickling), ``pending_respawns`` (spawned workers replaced
        before they ever connected), ``empty_slot_timeouts`` (dispatches
        that found no worker within ``connect_timeout``),
        ``workers_connected`` (live slots) and the aggregated
        transfer-cache ``cache_hits`` / ``cache_misses``."""
        out = super().stats()
        out["remote_jobs"] = sum(self._remote_jobs)
        out["worker_restarts"] = sum(self._restarts)
        out["worker_kills"] = sum(self._worker_kills)
        out["heartbeat_lapses"] = sum(self._hb_lapses)
        out["handshakes_rejected"] = self._rejected
        out["auth_failures"] = self._auth_failures
        out["pending_respawns"] = self._pending_respawns
        out["empty_slot_timeouts"] = self._empty_slot_timeouts
        hits = misses = connected = 0
        with self._proc_lock:
            for conn, cache in zip(self._conns, self._caches):
                if isinstance(conn, FramedConn):
                    connected += 1
                if cache is not None:
                    cs = cache.stats()
                    hits += cs["hits"]
                    misses += cs["misses"]
        out["workers_connected"] = connected
        out["cache_hits"] = hits
        out["cache_misses"] = misses
        return out

    def _teardown_net(self) -> None:
        """Stop network threads, close every connection and reap every
        worker process (spawned or pending)."""
        self._net_stop.set()
        try:
            self._listener.close()  # unblocks the acceptor
        except OSError:
            pass
        with self._proc_lock:
            conns = [c for c in self._conns if isinstance(c, FramedConn)]
            caches = [c for c in self._caches if c is not None]
            procs = [p for p in self._procs if p is not None] + self._pending_procs
            self._conns = [None] * self._n_slots
            self._caches = [None] * self._n_slots
            self._procs = [None] * self._n_slots
            self._pending_procs = []
        for conn in conns:
            try:
                conn.send(("bye",))  # orderly shutdown for remote workers
            except OSError:
                pass
        for proc in procs:
            proc.join(timeout=5.0)
            if proc.is_alive():  # pragma: no cover - wedged worker safety net
                proc.terminate()
                proc.join(timeout=1.0)
            try:
                proc.close()
            except Exception:
                pass
        for conn in conns:
            conn.close()
        for cache in caches:
            cache.close()
        for t in (self._accept_thread, self._monitor_thread):
            if t.is_alive():
                t.join(timeout=2.0)

    def close(self) -> None:
        """Stop dispatcher threads, then shut workers down and close every
        connection. In-flight bodies finish (their replies drain first);
        queued-but-unstarted tasks are abandoned, as in the base pool."""
        if self._stop:
            return
        super().close()  # joins dispatcher threads; replies drain first
        self._teardown_net()
