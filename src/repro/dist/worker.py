"""Worker-process entry point for the process backend (DESIGN.md §11).

Each worker is a plain loop over one duplex pipe to its dispatcher thread
in the parent — the scheduler never crosses the boundary, only task
*bodies* do. Job protocol (one in-flight job per worker, by construction —
the dispatcher thread blocks on the reply):

    parent -> worker   (job_id, fn_wire, args_wire)      run this body
    parent -> worker   None                              shut down
    worker -> parent   (job_id, True,  result_wire)      body returned
    worker -> parent   (job_id, False, exception_bytes)  body raised

``fn_wire``/``args_wire``/``result_wire`` are ``repro.dist.wire`` payloads;
arrays at/above the arena threshold ride shared memory (arguments via the
parent's pooled segments, results via per-send ephemeral segments — see
``shm_arena.py`` for the lifetime rules).

A worker catches *everything* a body raises — including ``SystemExit`` /
``KeyboardInterrupt`` — and reports it as a task failure; only pipe loss
(parent gone) or the shutdown sentinel ends the loop. A worker that dies
anyway (``os._exit``, OOM kill, segfault) surfaces in the parent as
``WorkerDiedError`` on the in-flight task, never as a hang.
"""
from __future__ import annotations

from typing import Any

from .shm_arena import ShmArena
from .wire import (
    dumps_exception,
    dumps_value,
    loads_args,
    loads_fn,
    shm_refs,
)

__all__ = ["worker_main"]


def worker_main(conn: Any, threshold: int) -> None:
    """Run jobs from ``conn`` until the shutdown sentinel or pipe loss.

    ``threshold`` is the arena cut-over (bytes): result arrays at or above
    it ship through ephemeral shared-memory segments.
    """
    arena = ShmArena(threshold, attach_only=True)
    try:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):  # parent died or closed the pipe
                return
            if msg is None:  # orderly shutdown
                return
            job_id, fn_wire, args_wire = msg
            try:
                fn = loads_fn(fn_wire, arena)
                args = loads_args(args_wire, arena)
                result = fn(*args)
                reply = (job_id, True, dumps_value(result, arena))
            except BaseException as exc:  # noqa: BLE001 - body verdicts travel home
                reply = (job_id, False, dumps_exception(exc))
            try:
                conn.send(reply)
            except (BrokenPipeError, OSError):
                # parent went away mid-reply: an undelivered result's
                # ephemeral segments would outlive both processes —
                # unlink them before exiting
                if reply[1]:
                    for ref in shm_refs(reply[2]):
                        arena.recycle(ref)
                return
    finally:
        arena.close()
        try:
            conn.close()
        except Exception:
            pass
