"""Process-pool execution backend: scheduler in the parent, bodies in
worker processes (DESIGN.md §11).

The GIL caps the thread backend at one CPU-bound *body* at a time; this
backend removes the cap without forking the scheduler. :class:`ProcessPool`
**is** a :class:`~repro.core.ThreadPool` — countdown tokens, condition
branches, subflow splices, counted completion, priorities, observers and
idle accounting all run unchanged in the parent — whose dispatcher threads
act as proxies: executing a *wired* task means sending ``(job_id, fn_wire,
args_wire)`` down a dedicated pipe to a paired worker process and blocking
(GIL released) on the reply. Everything the §9/§10 scheduler guarantees
holds verbatim, because the scheduler never moved.

Placement (DESIGN.md §11): conditions, ``takes_runtime`` spawners and
``fn=None`` bookkeeping tasks always run in-parent (they drive the
scheduler); ``affinity="local"`` pins a body in-parent; the default
``affinity="any"`` offloads when the body serializes and quietly runs
in-parent when it does not; ``affinity="remote"`` demands offload and
raises :class:`~repro.dist.wire.UnpicklableTaskError` **at submit** when
the body cannot ship. Remote bodies see a snapshot of their closures —
mutations do not travel back; results, exceptions and dataflow edge
values do (large arrays via the shared-memory arena).

Fault model (DESIGN.md §14): a worker that dies fails **that task** with
:class:`WorkerDiedError` — the dispatcher thread observes the broken
pipe, respawns a fresh worker, and the failure takes the normal §8 route
(dataflow adoption / future delivery / ``wait_idle`` raise). The pool
never hangs on a dead worker and never loses capacity. The error's
``started`` flag records *when* the worker died: ``False`` means the job
never left the parent (send hit a closed pipe — always safe to retry, and
the pool's implicit transport-loss :class:`~repro.core.RetryPolicy`
resubmits it once through the normal §14 machinery), ``True`` means the
body may have partially run. Started bodies are at-most-once by default —
retried only for tasks declared ``idempotent=True`` (and then only under
a matching policy, implicit or task-supplied). Tasks with ``timeout=``
get a hard watchdog: a timer kills the stuck worker process, the
dispatcher's blocked ``recv`` sees EOF, and the task fails with
:class:`~repro.core.TaskTimeoutError` instead.

Replay (DESIGN.md §12) composes through the two §11 seams rather than
around them: a captured :class:`~repro.core.ReplayPlan` re-arm calls
``_wire_tasks`` over the *member* tasks every pass, so placement decisions
(and any ``fn`` rebinding a consumer did between passes) are re-evaluated
exactly as a live submission would, and the replay run loop offloads each
wired member through ``_offload`` — fused segments ship their bodies one
by one, they are never serialized as a unit.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import threading
import time
from typing import Any, Optional, Sequence

from repro.core.pool import ThreadPool
from repro.core.task import RetryPolicy, Task, TaskTimeoutError

from .shm_arena import DEFAULT_THRESHOLD, ShmArena
from .wire import (
    UnpicklableTaskError,
    dumps_args,
    dumps_fn,
    loads_exception,
    loads_value,
    shm_refs,
)
from .worker import worker_main

__all__ = ["ProcessPool", "WorkerDiedError"]


class WorkerDiedError(RuntimeError):
    """The worker process assigned a task body died before replying.

    ``started`` gates the §14 retry decision: ``False`` means the job never
    reached the worker (the send hit a closed pipe) so a retry cannot
    double-execute anything; ``True`` means the body may have partially run
    — the pool retries it only for ``idempotent=True`` tasks. Either way
    the worker is respawned and the pool keeps serving.
    """

    def __init__(self, message: str, *, started: bool = False) -> None:
        super().__init__(message)
        self.started = started


# Transport loss is the pool's fault, not the body's: one implicit retry
# (DESIGN.md §14) replaces the old hardcoded "retry the send once" path,
# so send failures flow through the same observable machinery (on_retry,
# stats()["retries"]) as user-declared policies.
_TRANSPORT_RETRY = RetryPolicy(max_attempts=2, backoff=0.0, retry_on=WorkerDiedError)


class _WireError:
    """Deferred submit-time wiring failure for runtime-spawned tasks.

    Spawned tasks are wired inside the scheduler loop, where raising would
    poison the worker — instead the error is parked on ``task._wire`` and
    raised when the task body runs, taking the normal failure route.
    """

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException) -> None:
        self.exc = exc

    def raise_(self, _fn: Any, _args: tuple) -> None:
        raise self.exc


class ProcessPool(ThreadPool):
    """Work-stealing scheduler whose task bodies run in worker processes.

    Drop-in for :class:`~repro.core.ThreadPool` (same submit / wait_idle /
    observer / stats surface — ``Executor(backend="process")`` is the
    usual front door). One worker process and one dispatcher thread per
    slot; jobs and small values cross per-worker pipes, large arrays cross
    the shared-memory arena.

    Parameters
    ----------
    num_workers:
        Worker-process count (default ``os.cpu_count()``). Also the
        dispatcher-thread count in the parent.
    arena_threshold:
        Minimum array size (bytes) to route through shared memory instead
        of pickle (``repro.dist.shm_arena.DEFAULT_THRESHOLD`` = 32 KiB).
    arena_max_pooled:
        Cap on pooled arena segments (``None`` = unbounded). At the cap,
        oversize argument arrays degrade to one-shot ephemeral segments
        instead of growing the pool — see :meth:`ShmArena.stats
        <repro.dist.shm_arena.ShmArena.stats>`.
    mp_context:
        ``"fork"`` (default where available — cheap, inherits imported
        modules so lambdas defined anywhere resolve) or ``"spawn"``
        (slower, but immune to fork-with-threads hazards; bodies must live
        in importable modules).
    name, observers, deque_cls:
        Forwarded to :class:`~repro.core.ThreadPool`.

    Same pool surface, bodies in other processes::

        >>> from repro.dist import ProcessPool
        >>> with ProcessPool(2) as pool:
        ...     fut = pool.submit_future(lambda: sum(i * i for i in range(100)))
        ...     fut.result(30)
        328350
    """

    def __init__(
        self,
        num_workers: Optional[int] = None,
        *,
        arena_threshold: int = DEFAULT_THRESHOLD,
        arena_max_pooled: Optional[int] = None,
        mp_context: Optional[str] = None,
        name: str = "repro-procpool",
        observers: Sequence[Any] = (),
        **pool_kwargs: Any,
    ) -> None:
        n = num_workers if num_workers is not None else (os.cpu_count() or 1)
        if n < 1:
            raise ValueError("num_workers must be >= 1")
        ctx_name = mp_context or (
            "fork" if "fork" in mp.get_all_start_methods() else "spawn"
        )
        self._mp = mp.get_context(ctx_name)
        self._arena = ShmArena(arena_threshold, max_pooled=arena_max_pooled)
        self._worker_name = name
        self._conns: list[Any] = [None] * n
        self._procs: list[Any] = [None] * n
        self._job_seq = [0] * n  # per-worker job ids (one in flight each)
        self._remote_jobs = [0] * n
        self._restarts = [0] * n
        self._worker_kills = [0] * n  # watchdog SIGKILLs (§14 hard timeout)
        self._current_remote: list[Any] = [None] * n  # in-flight task per slot
        self._proc_lock = threading.Lock()  # serializes respawn bookkeeping
        # workers first (before any parent thread exists — fork safety),
        # then the scheduler, then the dispatch hooks
        for i in range(n):
            self._start_worker(i)
        super().__init__(n, name=name, observers=observers, **pool_kwargs)
        self._wire_tasks = self._wire_graph
        self._offload = self._offload_body

    # -- wiring (submit-time) ---------------------------------------------------

    def _wire_graph(self, tasks: Any, *, defer: bool = False) -> None:
        """Serialize every eligible body in ``tasks`` (the §11 placement
        rule); called by the base pool at each submission entry point and,
        with ``defer=True``, for runtime-spawned subflows."""
        for t in tasks:
            t._wire = self._wire_for(t, defer)

    @staticmethod
    def _wire_for(t: Task, defer: bool) -> Any:
        if (
            t.fn is None
            or t.takes_runtime
            or t.kind == "condition"
            or t.affinity == "local"
        ):
            return None  # scheduler-side by rule
        try:
            return dumps_fn(t.fn)
        except UnpicklableTaskError as exc:
            if t.affinity == "remote":
                err = UnpicklableTaskError(
                    f"task {t.name or t.fn!r} has affinity='remote' but its "
                    f"body cannot be shipped to a worker process: {exc}"
                )
                if defer:
                    return _WireError(err)
                raise err from exc
            return None  # affinity="any": quiet in-parent fallback

    # -- dispatch (worker-thread side) ------------------------------------------

    def _offload_body(self, task: Task, index: int) -> None:
        """Body-execution seam bound into ``ThreadPool._execute``."""
        wire = task._wire
        if wire is None:
            task.run()
        elif type(wire) is _WireError:
            task.run(invoke=wire.raise_)
        else:
            task.run(
                invoke=lambda fn, args: self._remote_call(index, wire, args, fn, task)
            )

    def _remote_call(
        self, index: int, fn_wire: tuple, args: tuple, fn: Any, task: Task
    ) -> Any:
        """Ship one job to worker ``index`` and block for its verdict."""
        self._job_seq[index] += 1
        job_id = self._job_seq[index]
        try:
            args_wire = dumps_args(args, self._arena)
        except Exception as exc:
            # the §11 "any" fallback extends to edge values: a dataflow
            # input that cannot cross the boundary runs the body in-parent
            # (thread/serial parity) — affinity="remote" keeps the clear
            # contract error instead of a raw pickle TypeError
            if task.affinity == "remote":
                raise UnpicklableTaskError(
                    f"task {task.name or fn!r} has affinity='remote' but a "
                    "dataflow input cannot be shipped to a worker process: "
                    f"{exc}"
                ) from exc
            return fn(*args)
        refs = shm_refs(args_wire)
        watched = task.timeout is not None
        if watched:
            # arm the §14 watchdog: remote bodies cannot reach the parent's
            # cooperative checkpoint, so the deadline escalates to a kill
            task._timed_out = False  # a prior kill may have raced the reply
            self._current_remote[index] = task
            self._timer_get().add(
                time.monotonic() + task.timeout,
                lambda a=task._attempt: self._hard_timeout(task, index, a),
            )
        try:
            conn = self._conns[index]
            try:
                conn.send((job_id, fn_wire, args_wire))
            except (BrokenPipeError, OSError):
                # worker died while idle: the job never left the parent —
                # respawn, then let the implicit transport-loss RetryPolicy
                # resubmit through the normal §14 scheduler path
                self._respawn(index)
                raise WorkerDiedError(
                    f"worker process {index} died before accepting a job",
                    started=False,
                ) from None
            try:
                reply = conn.recv()
            except (EOFError, OSError):
                # died mid-job: restore capacity, then fail the task —
                # TaskTimeoutError when the watchdog pulled the trigger,
                # WorkerDiedError(started=True) (at-most-once unless the
                # task declared itself idempotent) otherwise
                self._respawn(index)
                if task._timed_out:
                    raise TaskTimeoutError(
                        f"task {task.name!r} exceeded its {task.timeout}s "
                        f"timeout (worker process {index} killed)"
                    ) from None
                raise WorkerDiedError(
                    f"worker process {index} died while executing a task body",
                    started=True,
                ) from None
        finally:
            if watched:
                with self._proc_lock:  # fences the watchdog's is-check
                    self._current_remote[index] = None
            for ref in refs:
                self._arena.recycle(ref)
        jid, ok, payload = reply
        if jid != job_id:  # can only happen after a half-delivered respawn
            self._respawn(index)
            raise WorkerDiedError(f"worker {index} protocol desync (job {jid}!={job_id})")
        self._remote_jobs[index] += 1
        if ok:
            return loads_value(payload, self._arena)
        raise loads_exception(payload)

    # -- fault tolerance (DESIGN.md §14) -----------------------------------------

    def _retry_policy_for(self, task: Task, exc: BaseException) -> Any:
        """Task policy first (base rule); otherwise the implicit one-shot
        transport-loss retry for :class:`WorkerDiedError`. The base pool's
        at-most-once gate still blocks ``started=True`` losses for
        non-idempotent tasks regardless of which policy matched."""
        pol = super()._retry_policy_for(task, exc)
        if pol is None and isinstance(exc, WorkerDiedError):
            return _TRANSPORT_RETRY
        return pol

    def _hard_timeout(self, task: Task, index: int, attempt: int) -> None:
        """Timer-thread callback: SIGKILL the worker still running ``task``.

        The (task, attempt) pair guards against firing late — if the slot
        has moved on, or this very task was already retried onto a new
        attempt, the deadline belonged to an execution that no longer
        exists and the callback is a no-op.
        """
        with self._proc_lock:
            if self._current_remote[index] is not task or task._attempt != attempt:
                return
            task._timed_out = True
            self._worker_kills[index] += 1
            proc = self._procs[index]
        if proc is not None:
            proc.kill()  # dispatcher's recv sees EOF -> TaskTimeoutError

    # -- worker lifecycle --------------------------------------------------------

    def _start_worker(self, index: int) -> None:
        import warnings

        parent_conn, child_conn = self._mp.Pipe()
        proc = self._mp.Process(
            target=worker_main,
            args=(child_conn, self._arena.threshold),
            name=f"{self._worker_name}-w{index}",
            daemon=True,
        )
        with warnings.catch_warnings():
            # jax warns that fork + its internal threads can deadlock; the
            # worker loop never touches jax (device work stays on the
            # thread backend — DESIGN.md §11) and imports nothing new
            # post-fork. mp_context="spawn" exists for the cautious.
            warnings.filterwarnings(
                "ignore", message=".*fork.*", category=RuntimeWarning
            )
            proc.start()
        child_conn.close()  # parent keeps one end; EOF now means worker death
        self._conns[index] = parent_conn
        self._procs[index] = proc

    def _respawn(self, index: int) -> None:
        with self._proc_lock:
            self._restarts[index] += 1
            old_conn, old_proc = self._conns[index], self._procs[index]
            try:
                old_conn.close()
            except Exception:
                pass
            if old_proc is not None:
                old_proc.join(timeout=0.1)
                if old_proc.is_alive():  # pipe broke but process wedged
                    old_proc.terminate()
                    old_proc.join(timeout=1.0)
                try:
                    # release the dead worker's sentinel + pipe FDs *now* —
                    # parking them on the GC leaks FDs for the life of a
                    # draining pool (kill/respawn churn under chaos)
                    old_proc.close()
                except Exception:
                    pass
            self._start_worker(index)

    # -- lifecycle / stats -------------------------------------------------------

    def stats(self) -> dict[str, int]:
        """Base pool counters plus ``remote_jobs`` (bodies executed in
        worker processes), ``worker_restarts`` (respawns after death),
        ``worker_kills`` (§14 watchdog SIGKILLs of timed-out workers) and
        the nested ``arena`` segment-recycling counters (see
        :meth:`ShmArena.stats <repro.dist.shm_arena.ShmArena.stats>`)."""
        out = super().stats()
        out["remote_jobs"] = sum(self._remote_jobs)
        out["worker_restarts"] = sum(self._restarts)
        out["worker_kills"] = sum(self._worker_kills)
        out["arena"] = self._arena.stats()
        return out

    def close(self) -> None:
        """Stop dispatcher threads, then shut workers down and release the
        arena. In-flight bodies finish (their replies drain the pipes);
        queued-but-unstarted tasks are abandoned, as in the base pool."""
        if self._stop:
            return
        super().close()  # joins dispatcher threads; replies drain first
        for conn in self._conns:
            try:
                conn.send(None)  # shutdown sentinel
            except Exception:
                pass
        for proc in self._procs:
            if proc is None:
                continue
            proc.join(timeout=5.0)
            if proc.is_alive():  # pragma: no cover - wedged worker safety net
                proc.terminate()
                proc.join(timeout=1.0)
            try:
                proc.close()  # release sentinel FDs with the pool, not the GC
            except Exception:
                pass
        for conn in self._conns:
            try:
                conn.close()
            except Exception:
                pass
        self._arena.close()
