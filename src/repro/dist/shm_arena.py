"""Shared-memory arena for array edge values (DESIGN.md §11).

Large numpy/jax arrays crossing the parent↔worker boundary do not pickle
through the job pipe — the bytes go through POSIX shared memory and only a
small :class:`ArrayRef` descriptor crosses the pipe. Two segment kinds,
with different lifetimes:

* **Pooled segments** (parent → worker arguments). The parent's arena owns
  a freelist of segments bucketed by capacity; ``put`` copies the array
  into a recycled (or fresh) segment, ``recycle`` returns the segment to
  the freelist **after the job's reply arrives** — a worker reads its
  argument view zero-copy, so a segment must never be rewritten while the
  job that references it is still running. Pooled segments are unlinked
  when the arena closes (pool shutdown).

* **Ephemeral segments** (worker → parent results). The worker creates one
  segment per large result array and sends the descriptor; on receipt the
  parent copies the data out and unlinks the segment immediately. Lifetime
  is exactly send→receipt, so a result can never dangle on a segment whose
  creator died.

Attached views are only valid while the segment is: a worker body that
stows its zero-copy argument view somewhere global and reads it after the
job replied is out of contract (results are copied at encode time, so
*returning* a view is fine).

The socket transport (DESIGN.md §16) cannot share memory across hosts, so
it swaps the arena for a :class:`TransferCache` with the same duck-typed
surface (``threshold`` / ``put`` / ``get`` / ``recycle`` / ``close``):
large arrays ship inline in the frame **once**, keyed by a content hash,
and later sends of identical bytes ship only the 16-byte digest. Both
classes expose :meth:`ShmArena.stats` so pool ``stats()`` can surface
recycle/hit counters.

Doctest (same-process round trip)::

    >>> import numpy as np
    >>> from repro.dist.shm_arena import ShmArena
    >>> arena = ShmArena(threshold=0)
    >>> ref = arena.put(np.arange(6, dtype=np.int32).reshape(2, 3))
    >>> int(arena.get(ref).sum())
    15
    >>> arena.recycle(ref)   # back to the freelist for the next job
    >>> arena.close()
"""
from __future__ import annotations

import hashlib
import secrets
import threading
from multiprocessing import shared_memory

import numpy as np

__all__ = ["ArrayRef", "ShmArena", "CacheRef", "TransferCache", "DEFAULT_THRESHOLD"]

DEFAULT_THRESHOLD = 32 * 1024  # bytes; below this, pickle through the pipe wins


def _unregister(name: str) -> None:
    """Drop a segment from this process's resource tracker.

    ``SharedMemory`` registers every attach with the tracker, but only the
    owning side unlinks — without this, attach-only processes warn about
    "leaked" segments at shutdown (and under ``fork`` the shared tracker
    would try to double-unlink)."""
    try:  # pragma: no cover - depends on interpreter internals
        from multiprocessing import resource_tracker

        resource_tracker.unregister("/" + name, "shared_memory")
    except Exception:
        pass


class ArrayRef:
    """Descriptor of an array living in a shared-memory segment."""

    __slots__ = ("name", "shape", "dtype", "nbytes", "ephemeral")

    def __init__(
        self, name: str, shape: tuple, dtype: str, nbytes: int, ephemeral: bool
    ) -> None:
        self.name = name
        self.shape = shape
        self.dtype = dtype
        self.nbytes = nbytes
        self.ephemeral = ephemeral

    def __reduce__(self):
        return (
            ArrayRef,
            (self.name, self.shape, self.dtype, self.nbytes, self.ephemeral),
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        kind = "ephemeral" if self.ephemeral else "pooled"
        return f"ArrayRef({self.name}, {self.shape}, {self.dtype}, {kind})"


def _bucket(nbytes: int) -> int:
    """Segment capacity for a payload: next power of two ≥ 4 KiB, so
    recycled segments fit future arrays of similar size."""
    cap = 4096
    while cap < nbytes:
        cap <<= 1
    return cap


class ShmArena:
    """Process-shared scratch space for array edge values.

    One instance lives in the parent (owning the pooled freelist); each
    worker holds an *attach-only* instance (``attach_only=True``) that
    maps segments on demand and caches the mappings — pooled segment names
    are stable across jobs, so a steady-state worker maps no new memory.

    Parameters
    ----------
    threshold:
        Minimum ``nbytes`` for an array to travel through the arena;
        smaller arrays pickle through the pipe (cheaper than a segment
        round trip).
    attach_only:
        Worker-side mode: :meth:`put` creates ephemeral (per-result)
        segments instead of pooled ones, and :meth:`close` only drops
        local mappings — the parent owns every unlink.
    max_pooled:
        Cap on *owned* pooled segments (``None`` = unbounded). Once the
        cap is reached and the matching freelist bucket is empty, ``put``
        degrades to an ephemeral segment instead of blocking or growing —
        concurrent jobs stay deadlock-free at the cost of one extra copy
        per overflow (visible as ``ephemeral_created`` in :meth:`stats`).
    """

    def __init__(
        self,
        threshold: int = DEFAULT_THRESHOLD,
        *,
        attach_only: bool = False,
        max_pooled: int | None = None,
    ) -> None:
        self.threshold = threshold
        self._attach_only = attach_only
        self._max_pooled = max_pooled
        self._lock = threading.Lock()
        self._free: dict[int, list[shared_memory.SharedMemory]] = {}
        self._owned: dict[str, shared_memory.SharedMemory] = {}  # name -> seg
        # freelist key per segment: the *requested* bucket capacity, NOT
        # seg.size — the OS may page-round the mapping (macOS: 16 KiB), and
        # a recycle keyed on the rounded size would never match a checkout
        self._caps: dict[str, int] = {}
        self._attached: dict[str, shared_memory.SharedMemory] = {}
        self._closed = False
        self._counts = {
            "pooled_created": 0,
            "pooled_reused": 0,
            "pooled_recycled": 0,
            "ephemeral_created": 0,
            "ephemeral_unlinked": 0,
        }

    # -- write side -----------------------------------------------------------

    def put(self, array: np.ndarray) -> ArrayRef:
        """Copy ``array`` into a segment; returns the descriptor to ship.

        Parent side: a pooled segment (recycled via :meth:`recycle` once
        the referencing job completes). Worker side: a fresh ephemeral
        segment the parent will unlink on receipt.
        """
        arr = np.ascontiguousarray(array)
        seg = None if self._attach_only else self._checkout(_bucket(arr.nbytes))
        if seg is None:
            # worker side, or pooled capacity exhausted (max_pooled):
            # one-shot segment, unlinked by the receiving get()
            seg = shared_memory.SharedMemory(
                create=True, size=max(1, arr.nbytes), name=f"repro_r_{secrets.token_hex(8)}"
            )
            with self._lock:
                self._counts["ephemeral_created"] += 1
            ephemeral = True
        else:
            ephemeral = False
        view = np.ndarray(arr.shape, dtype=arr.dtype, buffer=seg.buf)
        view[...] = arr
        ref = ArrayRef(seg.name, tuple(arr.shape), str(arr.dtype), arr.nbytes, ephemeral)
        if ephemeral:
            # local mapping no longer needed; the parent copies + unlinks
            seg.close()
            _unregister(seg.name)
        return ref

    def _checkout(self, cap: int) -> shared_memory.SharedMemory | None:
        """A free or fresh pooled segment, or ``None`` at the ``max_pooled``
        cap (the caller falls back to an ephemeral segment)."""
        with self._lock:
            free = self._free.get(cap)
            if free:
                self._counts["pooled_reused"] += 1
                return free.pop()
            if self._max_pooled is not None and len(self._owned) >= self._max_pooled:
                return None  # strict cap — checked under the same lock as creation
            seg = shared_memory.SharedMemory(
                create=True, size=cap, name=f"repro_a_{secrets.token_hex(8)}"
            )
            self._owned[seg.name] = seg
            self._caps[seg.name] = cap
            self._counts["pooled_created"] += 1
        return seg

    def recycle(self, ref: ArrayRef) -> None:
        """Release a segment whose job is over: pooled refs go back to the
        freelist (the next job may rewrite them immediately — the caller
        guarantees the referencing job has replied); ephemeral refs are
        unlinked on the spot. The ephemeral case is the *failed-send*
        path: a result pack that never reached the parent would otherwise
        strand its ``repro_r_*`` segments until reboot (``get`` is the
        delivery-side release).
        """
        if ref.ephemeral:
            try:
                seg = shared_memory.SharedMemory(name=ref.name)
            except FileNotFoundError:  # already delivered + unlinked
                return
            try:
                seg.unlink()
            except Exception:
                pass
            seg.close()
            with self._lock:
                self._counts["ephemeral_unlinked"] += 1
            return
        with self._lock:
            seg = self._owned.get(ref.name)
            if seg is not None:
                self._free.setdefault(self._caps[ref.name], []).append(seg)
                self._counts["pooled_recycled"] += 1

    # -- read side ------------------------------------------------------------

    def get(self, ref: ArrayRef) -> np.ndarray:
        """Materialize an array from its descriptor.

        Pooled refs return a **zero-copy read view** (valid until the job
        replies); ephemeral refs are copied out and their segment unlinked
        on the spot (the receipt that ends the result's shm lifetime).
        """
        if ref.ephemeral:
            seg = shared_memory.SharedMemory(name=ref.name)
            try:
                view = np.ndarray(ref.shape, dtype=np.dtype(ref.dtype), buffer=seg.buf)
                out = np.array(view)  # own the bytes before the segment dies
            finally:
                try:
                    seg.unlink()  # receipt ends the result's shm lifetime
                except Exception:
                    pass
                seg.close()
            with self._lock:
                self._counts["ephemeral_unlinked"] += 1
            return out
        seg = self._attached.get(ref.name)
        if seg is None:
            seg = self._owned.get(ref.name)
        if seg is None:
            seg = shared_memory.SharedMemory(name=ref.name)
            _unregister(ref.name)
            with self._lock:
                self._attached[ref.name] = seg
        return np.ndarray(ref.shape, dtype=np.dtype(ref.dtype), buffer=seg.buf)

    # -- introspection ----------------------------------------------------------

    def stats(self) -> dict:
        """Segment-lifecycle counters (all monotonic except the gauges).

        Keys: ``pooled_created`` / ``pooled_reused`` / ``pooled_recycled``
        (freelist round trips), ``ephemeral_created`` / ``ephemeral_unlinked``
        (one-shot segments — worker results and ``max_pooled`` overflow),
        plus gauges ``pooled_segments`` (owned) and ``free_segments``
        (currently idle in the freelist).
        """
        with self._lock:
            out = dict(self._counts)
            out["pooled_segments"] = len(self._owned)
            out["free_segments"] = sum(len(v) for v in self._free.values())
        return out

    # -- lifecycle ------------------------------------------------------------

    def close(self) -> None:
        """Drop mappings; the owning side also unlinks its pooled segments."""
        if self._closed:
            return
        self._closed = True
        for seg in self._attached.values():
            try:
                seg.close()
            except Exception:
                pass
        self._attached.clear()
        for seg in self._owned.values():
            try:
                seg.close()
                seg.unlink()
            except Exception:
                pass
        self._owned.clear()
        self._caps.clear()
        self._free.clear()

    def __del__(self) -> None:  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass


class CacheRef:
    """Descriptor of an array travelling the socket transport (§16).

    ``data`` carries the raw bytes exactly once — the first time a given
    content digest crosses a connection; repeats ship ``data=None`` and
    the receiver resolves the digest from its side of the
    :class:`TransferCache`.
    """

    __slots__ = ("digest", "shape", "dtype", "nbytes", "data")

    def __init__(
        self, digest: str, shape: tuple, dtype: str, nbytes: int, data: bytes | None
    ) -> None:
        self.digest = digest
        self.shape = shape
        self.dtype = dtype
        self.nbytes = nbytes
        self.data = data

    def __reduce__(self):
        return (CacheRef, (self.digest, self.shape, self.dtype, self.nbytes, self.data))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        kind = "inline" if self.data is not None else "cached"
        return f"CacheRef({self.digest[:8]}, {self.shape}, {self.dtype}, {kind})"


class TransferCache:
    """Per-connection content-addressed stand-in for :class:`ShmArena`.

    Shared memory cannot cross hosts, so the socket transport ships large
    arrays inline in the job frame — but only the first time. ``put``
    hashes the bytes (+ dtype + shape) with ``blake2b`` and, when the
    digest was already sent over this connection, returns a
    :class:`CacheRef` carrying just the digest; the peer's ``get``
    resolves it from the bytes it stored at first receipt. In-order
    framing guarantees the data-carrying frame lands before any
    digest-only reference to it.

    Lifetime is the connection's: each (re)connected worker gets a fresh
    cache on both ends, so a respawn can never resolve a digest the new
    peer does not hold. Entries are never evicted — the cache lives
    exactly as long as its connection, and workloads re-sending the same
    large arrays are the point of the cache. ``recycle`` (the
    ``wire.py`` partial-failure hook) un-marks a digest whose inline
    frame was never delivered; delivered refs are *not* recycled (that
    would defeat the cache — the asymmetry with :meth:`ShmArena.recycle`
    is deliberate).

    Doctest (both ends of one connection)::

        >>> import numpy as np
        >>> from repro.dist.shm_arena import TransferCache
        >>> tx, rx = TransferCache(threshold=0), TransferCache(threshold=0)
        >>> a = np.arange(6, dtype=np.int32)
        >>> first = tx.put(a)          # bytes ride the frame
        >>> first.data is None
        False
        >>> again = tx.put(a)          # digest only
        >>> again.data is None
        True
        >>> int(rx.get(first).sum()), int(rx.get(again).sum())
        (15, 15)
        >>> tx.stats()["hits"], tx.stats()["misses"]
        (1, 1)
    """

    def __init__(self, threshold: int = DEFAULT_THRESHOLD) -> None:
        self.threshold = threshold
        self._lock = threading.Lock()
        self._sent: set[str] = set()  # digests the peer holds
        self._recv: dict[str, bytes] = {}  # digest -> bytes this side holds
        self._hits = 0
        self._misses = 0

    @staticmethod
    def _digest(data: bytes, dtype: str, shape: tuple) -> str:
        h = hashlib.blake2b(digest_size=16)
        h.update(dtype.encode())
        h.update(repr(shape).encode())
        h.update(data)
        return h.hexdigest()

    def put(self, array: np.ndarray) -> CacheRef:
        """Encode ``array`` for the frame: inline bytes on first sight of
        this content, digest-only afterwards."""
        arr = np.ascontiguousarray(array)
        data = arr.tobytes()
        digest = self._digest(data, str(arr.dtype), tuple(arr.shape))
        with self._lock:
            if digest in self._sent:
                self._hits += 1
                return CacheRef(digest, tuple(arr.shape), str(arr.dtype), arr.nbytes, None)
            self._sent.add(digest)
            self._misses += 1
        return CacheRef(digest, tuple(arr.shape), str(arr.dtype), arr.nbytes, data)

    def get(self, ref: CacheRef) -> np.ndarray:
        """Materialize an array from its descriptor, remembering inline
        bytes for future digest-only refs. Always returns a fresh
        writable array (no zero-copy views — nothing shares the buffer)."""
        if ref.data is not None:
            with self._lock:
                self._recv[ref.digest] = ref.data
            buf = ref.data
        else:
            with self._lock:
                buf = self._recv.get(ref.digest)
            if buf is None:
                raise KeyError(
                    f"transfer cache has no bytes for digest {ref.digest!r} — "
                    "a digest-only ref arrived before (or without) its inline frame"
                )
        return np.frombuffer(buf, dtype=np.dtype(ref.dtype)).reshape(ref.shape).copy()

    def recycle(self, ref: CacheRef) -> None:
        """Forget an *undelivered* inline ref (``wire.py`` calls this when
        a multi-arg encode fails partway): its digest was optimistically
        marked sent at ``put`` time but the frame never went out, so the
        mark must not satisfy a future ``put``. Digest-only refs and
        delivered refs are no-ops."""
        if ref.data is not None:
            with self._lock:
                self._sent.discard(ref.digest)

    def stats(self) -> dict:
        """Cache effectiveness counters: ``hits`` (arrays sent as digest
        only), ``misses`` (arrays shipped inline), and the live entry
        gauges ``sent_digests`` / ``recv_digests``."""
        with self._lock:
            return {
                "hits": self._hits,
                "misses": self._misses,
                "sent_digests": len(self._sent),
                "recv_digests": len(self._recv),
            }

    def close(self) -> None:
        with self._lock:
            self._sent.clear()
            self._recv.clear()
