"""Shared-memory arena for array edge values (DESIGN.md §11).

Large numpy/jax arrays crossing the parent↔worker boundary do not pickle
through the job pipe — the bytes go through POSIX shared memory and only a
small :class:`ArrayRef` descriptor crosses the pipe. Two segment kinds,
with different lifetimes:

* **Pooled segments** (parent → worker arguments). The parent's arena owns
  a freelist of segments bucketed by capacity; ``put`` copies the array
  into a recycled (or fresh) segment, ``recycle`` returns the segment to
  the freelist **after the job's reply arrives** — a worker reads its
  argument view zero-copy, so a segment must never be rewritten while the
  job that references it is still running. Pooled segments are unlinked
  when the arena closes (pool shutdown).

* **Ephemeral segments** (worker → parent results). The worker creates one
  segment per large result array and sends the descriptor; on receipt the
  parent copies the data out and unlinks the segment immediately. Lifetime
  is exactly send→receipt, so a result can never dangle on a segment whose
  creator died.

Attached views are only valid while the segment is: a worker body that
stows its zero-copy argument view somewhere global and reads it after the
job replied is out of contract (results are copied at encode time, so
*returning* a view is fine).

Doctest (same-process round trip)::

    >>> import numpy as np
    >>> from repro.dist.shm_arena import ShmArena
    >>> arena = ShmArena(threshold=0)
    >>> ref = arena.put(np.arange(6, dtype=np.int32).reshape(2, 3))
    >>> int(arena.get(ref).sum())
    15
    >>> arena.recycle(ref)   # back to the freelist for the next job
    >>> arena.close()
"""
from __future__ import annotations

import secrets
import threading
from multiprocessing import shared_memory

import numpy as np

__all__ = ["ArrayRef", "ShmArena", "DEFAULT_THRESHOLD"]

DEFAULT_THRESHOLD = 32 * 1024  # bytes; below this, pickle through the pipe wins


def _unregister(name: str) -> None:
    """Drop a segment from this process's resource tracker.

    ``SharedMemory`` registers every attach with the tracker, but only the
    owning side unlinks — without this, attach-only processes warn about
    "leaked" segments at shutdown (and under ``fork`` the shared tracker
    would try to double-unlink)."""
    try:  # pragma: no cover - depends on interpreter internals
        from multiprocessing import resource_tracker

        resource_tracker.unregister("/" + name, "shared_memory")
    except Exception:
        pass


class ArrayRef:
    """Descriptor of an array living in a shared-memory segment."""

    __slots__ = ("name", "shape", "dtype", "nbytes", "ephemeral")

    def __init__(
        self, name: str, shape: tuple, dtype: str, nbytes: int, ephemeral: bool
    ) -> None:
        self.name = name
        self.shape = shape
        self.dtype = dtype
        self.nbytes = nbytes
        self.ephemeral = ephemeral

    def __reduce__(self):
        return (
            ArrayRef,
            (self.name, self.shape, self.dtype, self.nbytes, self.ephemeral),
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        kind = "ephemeral" if self.ephemeral else "pooled"
        return f"ArrayRef({self.name}, {self.shape}, {self.dtype}, {kind})"


def _bucket(nbytes: int) -> int:
    """Segment capacity for a payload: next power of two ≥ 4 KiB, so
    recycled segments fit future arrays of similar size."""
    cap = 4096
    while cap < nbytes:
        cap <<= 1
    return cap


class ShmArena:
    """Process-shared scratch space for array edge values.

    One instance lives in the parent (owning the pooled freelist); each
    worker holds an *attach-only* instance (``attach_only=True``) that
    maps segments on demand and caches the mappings — pooled segment names
    are stable across jobs, so a steady-state worker maps no new memory.

    Parameters
    ----------
    threshold:
        Minimum ``nbytes`` for an array to travel through the arena;
        smaller arrays pickle through the pipe (cheaper than a segment
        round trip).
    attach_only:
        Worker-side mode: :meth:`put` creates ephemeral (per-result)
        segments instead of pooled ones, and :meth:`close` only drops
        local mappings — the parent owns every unlink.
    """

    def __init__(
        self, threshold: int = DEFAULT_THRESHOLD, *, attach_only: bool = False
    ) -> None:
        self.threshold = threshold
        self._attach_only = attach_only
        self._lock = threading.Lock()
        self._free: dict[int, list[shared_memory.SharedMemory]] = {}
        self._owned: dict[str, shared_memory.SharedMemory] = {}  # name -> seg
        # freelist key per segment: the *requested* bucket capacity, NOT
        # seg.size — the OS may page-round the mapping (macOS: 16 KiB), and
        # a recycle keyed on the rounded size would never match a checkout
        self._caps: dict[str, int] = {}
        self._attached: dict[str, shared_memory.SharedMemory] = {}
        self._closed = False

    # -- write side -----------------------------------------------------------

    def put(self, array: np.ndarray) -> ArrayRef:
        """Copy ``array`` into a segment; returns the descriptor to ship.

        Parent side: a pooled segment (recycled via :meth:`recycle` once
        the referencing job completes). Worker side: a fresh ephemeral
        segment the parent will unlink on receipt.
        """
        arr = np.ascontiguousarray(array)
        if self._attach_only:
            seg = shared_memory.SharedMemory(
                create=True, size=max(1, arr.nbytes), name=f"repro_r_{secrets.token_hex(8)}"
            )
            ephemeral = True
        else:
            seg = self._checkout(_bucket(arr.nbytes))
            ephemeral = False
        view = np.ndarray(arr.shape, dtype=arr.dtype, buffer=seg.buf)
        view[...] = arr
        ref = ArrayRef(seg.name, tuple(arr.shape), str(arr.dtype), arr.nbytes, ephemeral)
        if ephemeral:
            # local mapping no longer needed; the parent copies + unlinks
            seg.close()
            _unregister(seg.name)
        return ref

    def _checkout(self, cap: int) -> shared_memory.SharedMemory:
        with self._lock:
            free = self._free.get(cap)
            if free:
                return free.pop()
        seg = shared_memory.SharedMemory(
            create=True, size=cap, name=f"repro_a_{secrets.token_hex(8)}"
        )
        with self._lock:
            self._owned[seg.name] = seg
            self._caps[seg.name] = cap
        return seg

    def recycle(self, ref: ArrayRef) -> None:
        """Release a segment whose job is over: pooled refs go back to the
        freelist (the next job may rewrite them immediately — the caller
        guarantees the referencing job has replied); ephemeral refs are
        unlinked on the spot. The ephemeral case is the *failed-send*
        path: a result pack that never reached the parent would otherwise
        strand its ``repro_r_*`` segments until reboot (``get`` is the
        delivery-side release).
        """
        if ref.ephemeral:
            try:
                seg = shared_memory.SharedMemory(name=ref.name)
            except FileNotFoundError:  # already delivered + unlinked
                return
            try:
                seg.unlink()
            except Exception:
                pass
            seg.close()
            return
        with self._lock:
            seg = self._owned.get(ref.name)
            if seg is not None:
                self._free.setdefault(self._caps[ref.name], []).append(seg)

    # -- read side ------------------------------------------------------------

    def get(self, ref: ArrayRef) -> np.ndarray:
        """Materialize an array from its descriptor.

        Pooled refs return a **zero-copy read view** (valid until the job
        replies); ephemeral refs are copied out and their segment unlinked
        on the spot (the receipt that ends the result's shm lifetime).
        """
        if ref.ephemeral:
            seg = shared_memory.SharedMemory(name=ref.name)
            try:
                view = np.ndarray(ref.shape, dtype=np.dtype(ref.dtype), buffer=seg.buf)
                out = np.array(view)  # own the bytes before the segment dies
            finally:
                try:
                    seg.unlink()  # receipt ends the result's shm lifetime
                except Exception:
                    pass
                seg.close()
            return out
        seg = self._attached.get(ref.name)
        if seg is None:
            seg = self._owned.get(ref.name)
        if seg is None:
            seg = shared_memory.SharedMemory(name=ref.name)
            _unregister(ref.name)
            with self._lock:
                self._attached[ref.name] = seg
        return np.ndarray(ref.shape, dtype=np.dtype(ref.dtype), buffer=seg.buf)

    # -- lifecycle ------------------------------------------------------------

    def close(self) -> None:
        """Drop mappings; the owning side also unlinks its pooled segments."""
        if self._closed:
            return
        self._closed = True
        for seg in self._attached.values():
            try:
                seg.close()
            except Exception:
                pass
        self._attached.clear()
        for seg in self._owned.values():
            try:
                seg.close()
                seg.unlink()
            except Exception:
                pass
        self._owned.clear()
        self._caps.clear()
        self._free.clear()

    def __del__(self) -> None:  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass
