"""repro.serve — continuous-batching inference engine on the task-graph
thread pool (DESIGN.md §7).

``kv.py`` owns the per-family KV-cache layout knowledge (GQA append, MLA
compressed latents, SSM recurrent state, sliding-window rings) as a
slot-based cache pool; ``engine.py`` schedules prefill/decode as prioritized
tasks on the work-stealing pool and batches sequences at iteration level.
"""
from .engine import GenRequest, RequestHandle, ServeEngine
from .kv import SlotKVCache, pad_caches_to

__all__ = ["ServeEngine", "GenRequest", "RequestHandle", "SlotKVCache", "pad_caches_to"]
