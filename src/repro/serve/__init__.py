"""repro.serve — continuous-batching inference engine on the task-graph
thread pool (DESIGN.md §7, §13).

``kv.py`` owns the per-family KV-cache layout knowledge (GQA append, MLA
compressed latents, SSM recurrent state, sliding-window rings) as two
cache pools — the flat per-slot :class:`SlotKVCache` and the block-pooled
:class:`PagedKVCache` (fixed-size pages + per-sequence page tables);
``engine.py`` schedules prefill/decode as prioritized tasks on the
work-stealing pool, batches sequences at iteration level, streams tokens
per tick, and under page pressure preempts the youngest resident back to
its deadline-ordered admit queue.
"""
from .engine import (
    DECODE_PRIORITY,
    PREFILL_PRIORITY,
    PREFILL_SOON,
    PREFILL_URGENT,
    DeadlineExceeded,
    GenRequest,
    QueueFull,
    RequestHandle,
    ServeEngine,
)
from .kv import PagedKVCache, SlotKVCache, pad_caches_to

__all__ = [
    "ServeEngine",
    "GenRequest",
    "RequestHandle",
    "QueueFull",
    "DeadlineExceeded",
    "SlotKVCache",
    "PagedKVCache",
    "pad_caches_to",
    "PREFILL_PRIORITY",
    "PREFILL_SOON",
    "PREFILL_URGENT",
    "DECODE_PRIORITY",
]
