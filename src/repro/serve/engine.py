"""Continuous-batching inference engine on the work-stealing pool.

The serving path is expressed as prioritized tasks on the paper's thread
pool (DESIGN.md §7):

* **prefill** tasks run at LOW priority — they are pure (compute a batch-1
  cache + first token, touch no shared buffers) and arbitrarily parallel, so
  they soak up idle workers without ever delaying a decode step;
* **decode ticks** run at HIGH priority — the same B-before-F idea that
  makes the schedule simulator reproduce 1F1B: drain work that frees
  resources (finishing sequences release cache pages) before admitting more.

Under load the gap between those two bands is graded (DESIGN.md §13):
requests carry an optional **deadline**, and waiting prefills are promoted
through the §9 priority bands as their headroom shrinks —
``PREFILL_PRIORITY`` (fresh) < ``PREFILL_SOON`` (half the budget gone) <
``PREFILL_URGENT`` (three quarters gone) — so a near-deadline prefill
outranks fresh arrivals without ever outranking the decode tick. The admit
queue is a deadline-ordered heap bounded by ``max_waiting``
(:class:`QueueFull` backpressure instead of unbounded growth), and a
request whose deadline lapses before its prefill starts fails fast with
:class:`DeadlineExceeded` rather than occupying a slot it can no longer
use.

The engine batches at *iteration level*: between two decode ticks it joins
freshly prefilled sequences into free cache slots and retires finished ones,
so the padded decode batch tracks live traffic instead of a static batch
running to the longest member. One tick is one jitted
``vmap(model.decode_step)`` over the slot axis with a per-slot write index —
sequences of different lengths share one decode computation.

KV storage defaults to the **paged** layout (:class:`~repro.serve.kv.
PagedKVCache`): each tick gathers the resident sequences' pages into the
logical slot batch, decodes, and scatters back only the single page each
lane wrote. Admission holds pages for the prefilled prompt only; decode
growth claims pages one at a time, and on page pressure the engine
**preempts the youngest resident** — its pages are freed and the request
re-enters the admit queue (at its original deadline/arrival key) to resume
later by re-prefilling its prompt + generated prefix. Preemption moves
work, it never drops it. ``kv_layout="flat"`` keeps the original
whole-slot :class:`~repro.serve.kv.SlotKVCache` for comparison.

Tokens are **streamed**: every decode tick pushes each lane's new token to
its :class:`RequestHandle`, which exposes a blocking iterator
(``for tok in handle``) and an ``async for`` surface over the §10 asyncio
bridge, plus per-request latency marks (``submit_t``, ``first_token_t``,
``token_times`` — TTFT and inter-token gaps fall out).

Ticks form a **condition-cycle graph** (DESIGN.md §10) submitted through
the :class:`~repro.core.Executor` facade:

    entry -> decode-tick -> more? (condition)
                 ^______________|   (weak back-edge while work remains)

The loop serializes all mutation of the shared KV pools exactly as the
old self-rescheduling chain did, but the steady-state hop from tick to
tick is a weak-edge trigger inside a worker — no per-tick task allocation,
no external submission, no inbox lock. The graph is (re)started only when
work arrives on an idle engine, handed off through the run future's done
callback so a restart can never overlap a draining run. Admission and
queue bookkeeping stay lock-protected and may run from any thread.

Because the tick graph never changes shape, every restart after the first
dispatches from its captured :class:`~repro.core.ReplayPlan` (DESIGN.md
§12): the ``[decode-tick, more?]`` pair runs as one fused segment whose
weak back-edge loops without re-walking the live graph, and re-starting a
drained run costs a plan re-arm instead of a full reset + re-wire.
``stats()["tick_replays"]`` counts how many restarts took the replay path.

``submit_async`` rides the same facade's asyncio bridge: an async server
can ``tokens = await engine.submit_async(prompt, n)`` without blocking its
event loop, or stream with ``async for tok in engine.submit(...)``.
"""
from __future__ import annotations

import heapq
import itertools
import math
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChromeTraceObserver,
    Executor,
    Future,
    RetryPolicy,
    Task,
    TaskGraph,
    ThreadPool,
)

from .kv import PagedKVCache, SlotKVCache

__all__ = [
    "ServeEngine",
    "GenRequest",
    "RequestHandle",
    "QueueFull",
    "DeadlineExceeded",
    "PREFILL_PRIORITY",
    "PREFILL_SOON",
    "PREFILL_URGENT",
    "DECODE_PRIORITY",
]

# §9 priority bands for the serve path: decode always outranks admission
# work; within admission, deadline headroom grades the prefill band.
PREFILL_PRIORITY = -1.0  # fresh prefill / no deadline
PREFILL_SOON = -0.5  # more than half the deadline budget consumed
PREFILL_URGENT = 0.0  # more than three quarters consumed, or a resume
DECODE_PRIORITY = 1.0


class QueueFull(RuntimeError):
    """Backpressure: the bounded admit queue is at ``max_waiting``."""


class DeadlineExceeded(TimeoutError):
    """The request's deadline lapsed before its prefill started."""


class _PrefillRetry(RetryPolicy):
    """§14 policy for prefill tasks: transient failures retry (the compute
    is pure — params + prompt in, logits out — so a retried prefill is
    bit-identical), but a lapsed TTFT deadline is not transient and is
    surfaced immediately. Each retry attempt re-checks the deadline, so
    backoff can never extend a request past its TTFT budget."""

    def matches(self, exc: BaseException) -> bool:
        return not isinstance(exc, DeadlineExceeded) and super().matches(exc)


@dataclass(frozen=True)
class GenRequest:
    """One generation request: prompt token ids + greedy-decode budget.

    ``deadline`` (seconds from submission, optional) bounds time-to-first-
    token: it grades the prefill's §9 priority band as it ages and fails
    the request with :class:`DeadlineExceeded` if the prefill has not
    started when it lapses. It never interrupts a resident sequence.
    """

    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    deadline: Optional[float] = None


class RequestHandle:
    """Client-side handle: a cancellable future over the generated tokens,
    plus a streaming surface and per-request latency marks.

    ``result()`` returns the generated token ids as a 1-D int32 array (the
    prompt is not echoed). ``cancel()`` succeeds only while the request has
    not yet joined the decode batch (cooperative semantics — resident
    work runs to completion); a successful cancel releases anything the
    request held and the future resolves with ``CancelledError``.
    ``truncated`` is set when the sequence was evicted at cache capacity
    before reaching its token budget.

    Streaming: tokens are pushed per decode tick. ``for tok in handle``
    blocks the calling thread per token; ``async for tok in handle`` rides
    the §10 asyncio bridge and never blocks the event loop. Both raise the
    request's failure (including ``CancelledError``) at the point of
    failure and end cleanly on completion.

    Latency marks (``time.monotonic`` seconds): ``submit_t`` at submission,
    ``first_token_t`` when the first token is delivered (TTFT =
    ``first_token_t - submit_t``, also exposed as ``.ttft``), and
    ``token_times`` for every delivered token (inter-token gaps).
    """

    def __init__(
        self,
        rid: int,
        prompt_len: int,
        canceller,
        deadline: Optional[float] = None,
    ) -> None:
        self.rid = rid
        self.prompt_len = prompt_len
        self.deadline = deadline
        self.truncated = False
        self.submit_t = time.monotonic()
        self.first_token_t: Optional[float] = None
        self.token_times: list[float] = []
        self._cv = threading.Condition()
        self._streamed: list[int] = []
        self._listeners: list = []
        self.future = Future(canceller=canceller)
        # resolution (result, error or cancel) must wake stream consumers;
        # done callbacks fire on the resolving thread after first-write-wins
        self.future.add_done_callback(lambda _f: self._wake())

    # -- results ------------------------------------------------------------

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        return self.future.result(timeout)

    def cancel(self) -> bool:
        return self.future.cancel()

    def done(self) -> bool:
        return self.future.done()

    @property
    def ttft(self) -> Optional[float]:
        """Seconds from submission to first delivered token (None until)."""
        t = self.first_token_t
        return None if t is None else t - self.submit_t

    # -- streaming ----------------------------------------------------------

    def _push(self, tok: int) -> None:
        now = time.monotonic()
        with self._cv:
            if self.first_token_t is None:
                self.first_token_t = now
            self._streamed.append(int(tok))
            self.token_times.append(now)
            self._cv.notify_all()
            listeners = list(self._listeners)
        for cb in listeners:
            cb()

    def _wake(self) -> None:
        with self._cv:
            self._cv.notify_all()
            listeners = list(self._listeners)
        for cb in listeners:
            cb()

    def iter_tokens(self, timeout: Optional[float] = None) -> Iterator[int]:
        """Yield tokens as they are generated; ``timeout`` bounds each wait.

        Ends when the request completes; raises its failure (including
        ``CancelledError``) once all delivered tokens have been yielded.
        """
        i = 0
        while True:
            with self._cv:
                if not self._cv.wait_for(
                    lambda: len(self._streamed) > i or self.future.done(), timeout
                ):
                    raise TimeoutError("no token within timeout")
                # tokens are pushed strictly before the future resolves, so
                # a done future with no pending tokens is final
                have, fin = len(self._streamed), self.future.done()
            while i < have:
                yield self._streamed[i]
                i += 1
            if fin:
                self.future.result(0)  # surface error / cancellation
                return

    def __iter__(self) -> Iterator[int]:
        return self.iter_tokens()

    async def stream(self):
        """``async for tok in handle.stream()`` (also ``async for ... in
        handle``): per-token delivery without blocking the event loop."""
        import asyncio

        loop = asyncio.get_running_loop()
        evt = asyncio.Event()

        def poke() -> None:
            try:
                loop.call_soon_threadsafe(evt.set)
            except RuntimeError:  # loop already closed
                pass

        with self._cv:
            self._listeners.append(poke)
        i = 0
        try:
            while True:
                evt.clear()  # before the snapshot: a wake after it re-sets
                with self._cv:
                    have, fin = len(self._streamed), self.future.done()
                while i < have:
                    yield self._streamed[i]
                    i += 1
                if fin:
                    self.future.result(0)
                    return
                await evt.wait()
        finally:
            with self._cv:
                if poke in self._listeners:
                    self._listeners.remove(poke)

    def __aiter__(self):
        return self.stream()


class _Pending:
    """A request between submission and residency (admit queue / prefill /
    join queue). ``tokens`` is non-empty iff this is a preempted sequence
    awaiting resume. Heap key: (deadline or +inf, arrival order)."""

    __slots__ = (
        "handle", "req", "deadline", "order", "tokens", "cancelled", "stage", "joined",
    )

    def __init__(
        self, handle: RequestHandle, req: GenRequest, deadline: Optional[float], order: int
    ) -> None:
        self.handle = handle
        self.req = req
        self.deadline = deadline  # absolute monotonic, or None
        self.order = order
        self.tokens: list[int] = []
        self.cancelled = False
        self.stage = "waiting"  # waiting -> prefill -> join -> (active)
        self.joined: Optional[tuple] = None  # (cache, first_token, pad)

    @property
    def key(self) -> tuple:
        return (self.deadline if self.deadline is not None else math.inf, self.order)


class _Seq:
    """A live sequence occupying one cache slot."""

    __slots__ = ("p", "tokens", "feed_index", "remaining", "slot")

    def __init__(
        self, p: _Pending, tokens: list, feed_index: int, remaining: int, slot: int
    ) -> None:
        self.p = p
        self.tokens = tokens
        self.feed_index = feed_index  # position of the token fed next tick
        self.remaining = remaining
        self.slot = slot

    @property
    def handle(self) -> RequestHandle:
        return self.p.handle


class ServeEngine:
    """Continuous-batching greedy-decode engine.

    Parameters
    ----------
    model, params:
        A ``repro.models.Model`` and its parameter tree. Encoder-decoder and
        VLM families are not supported (their prefill inputs are not plain
        token prompts).
    max_slots:
        Decode batch width = number of resident sequences.
    max_len:
        Per-sequence cache capacity (prompt + generated). Sequences reaching
        it are evicted (``handle.truncated``).
    kv_layout:
        ``"paged"`` (default) stores growable cache leaves in fixed-size
        pages with per-sequence page tables (DESIGN.md §13) — admission
        holds pages for the prompt only, growth is O(1) page claims, and
        page pressure preempts the youngest resident to the admit queue
        instead of refusing work. ``"flat"`` keeps the whole-slot layout.
    page_size, num_pages:
        Paged layout knobs: tokens per page, and the usable page-pool size.
        ``num_pages`` defaults to ``max_slots * ceil(max_len / page_size)``
        (every resident can reach ``max_len`` — no preemption); smaller
        values oversubscribe memory and rely on preemption.
    max_waiting:
        Bound on the admit queue. ``submit`` raises :class:`QueueFull` when
        this many requests are already waiting (None = unbounded).
        Preemption re-entries bypass the bound — they were already admitted.
    pool:
        Shared :class:`ThreadPool`; the engine owns a 2-worker pool if None.
    prefill_buckets:
        Optional ascending prompt-length buckets. Prompts are right-padded to
        the smallest fitting bucket so prefill compiles once per bucket
        instead of once per length. Only valid for full-attention families
        (pad tokens are causally invisible and masked by ``valid_len`` during
        decode); SSM/hybrid state and sliding-window rings would absorb the
        pad tokens, so bucketing is rejected there.
    prefill_lookahead:
        How many prefills may run/wait beyond free slot capacity (default:
        ``max_slots``). Speculative prefills keep the join queue warm so a
        retiring sequence is replaced at the very next tick; each waiting
        join holds one batch-1 cache of bucket length, which bounds the
        extra memory.
    trace_path:
        When set, a :class:`~repro.core.ChromeTraceObserver` is attached to
        the pool for the engine's lifetime and the trace (every prefill
        task, decode tick and steal, per worker lane) is written there on
        ``close()`` — load it in ``chrome://tracing``. Exposed as
        ``self.tracer`` for mid-run snapshots (``tracer.to_trace()``). On a
        shared pool the trace includes the other users' tasks too, which is
        usually what you want when diagnosing interference.
    """

    def __init__(
        self,
        model,
        params,
        *,
        max_slots: int = 8,
        max_len: int = 256,
        kv_layout: str = "paged",
        page_size: int = 64,
        num_pages: Optional[int] = None,
        max_waiting: Optional[int] = None,
        pool: Optional[ThreadPool] = None,
        prefill_buckets: Optional[Sequence[int]] = None,
        prefill_lookahead: Optional[int] = None,
        trace_path: Optional[str] = None,
        prefill_retries: int = 2,
        breaker_threshold: int = 5,
        breaker_cooldown: float = 1.0,
    ) -> None:
        cfg = model.cfg
        if cfg.is_encdec or cfg.family == "vlm":
            raise NotImplementedError(
                f"ServeEngine supports text-prompt families only, got {cfg.family!r}"
            )
        if prefill_buckets is not None and not self.supports_prefill_buckets(cfg):
            raise ValueError(
                "prefill_buckets requires a full-attention family (no SSM state, "
                f"no sliding window); {cfg.name} would absorb pad tokens"
            )
        self.model = model
        self.params = params
        self.pool = pool or ThreadPool(2, name="serve")
        self._own_pool = pool is None
        self._trace_path = trace_path
        self.tracer: Optional[ChromeTraceObserver] = None
        if trace_path is not None:
            self.tracer = ChromeTraceObserver()
            self.pool.add_observer(self.tracer)
        self._buckets = tuple(sorted(prefill_buckets)) if prefill_buckets else None
        self._lookahead = max_slots if prefill_lookahead is None else prefill_lookahead
        self._max_waiting = max_waiting
        # §14 graceful degradation: transient prefill failures retry under
        # the TTFT deadline; sustained failure trips a circuit breaker that
        # sheds load fast (QueueFull) instead of queueing doomed requests.
        self._prefill_retry = (
            _PrefillRetry(max_attempts=1 + prefill_retries, backoff=0.005, factor=2.0)
            if prefill_retries > 0
            else None
        )
        self._breaker_threshold = breaker_threshold
        self._breaker_cooldown = breaker_cooldown
        self._breaker_fails = 0  # consecutive exhausted prefill failures
        self._breaker_until = 0.0  # monotonic time the breaker re-closes
        self._breaker_trips = 0
        self._prefill_jit = jax.jit(model.prefill)

        def _step(p, tok, cache, idx):
            logits, cache = model.decode_step(p, tok, cache, idx)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

        if kv_layout == "paged":
            self.kv = PagedKVCache(
                model, max_slots, max_len, page_size=page_size, num_pages=num_pages
            )
            kv = self.kv

            def _ptick(p, tok, pools, tables, dest, idx):
                caches = kv.gather(pools, tables)
                toks, upd = jax.vmap(_step, in_axes=(None, 0, 0, 0))(p, tok, caches, idx)
                return toks, kv.scatter(pools, upd, dest, idx)

            self._tick_jit = jax.jit(_ptick, donate_argnums=(2,))
        elif kv_layout == "flat":
            self.kv = SlotKVCache(model, max_slots, max_len)
            self._tick_jit = jax.jit(
                jax.vmap(_step, in_axes=(None, 0, 0, 0)), donate_argnums=(2,)
            )
        else:
            raise ValueError(f"kv_layout must be 'paged' or 'flat', got {kv_layout!r}")
        self._paged = kv_layout == "paged"

        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._waiting: list = []  # heap of (key, _Pending)
        self._nwaiting = 0  # non-cancelled heap entries
        self._pending_by_rid: dict[int, _Pending] = {}
        self._inflight = 0  # prefill tasks in flight
        self._joinq: deque = deque()  # (_Pending, cache, first_token, pad_len)
        self._active: dict[int, _Seq] = {}
        # -- the condition-cycle tick graph (module docs): built once,
        # looped by its weak back-edge, restarted only from idle.
        self._exec = Executor(pool=self.pool)
        tg = TaskGraph("serve-tick")
        entry = tg.add(None, name="tick-entry", priority=DECODE_PRIORITY)
        tick = tg.add(self._tick, name="decode-tick", priority=DECODE_PRIORITY)
        tick.after(entry)
        more = tg.add(
            self._tick_more, name="more?", kind="condition", priority=DECODE_PRIORITY
        )
        more.after(tick)
        more.precede(tick)  # branch 0: weak back-edge -> next tick
        for t in tg.tasks:
            t.propagate_errors = False
        self._tick_graph = tg
        self._tick_live = False  # a run of the tick graph is in flight
        self._closed = False
        self._broken: Optional[BaseException] = None
        self._rid = itertools.count()
        self._order = itertools.count()
        self._requests = 0
        self._completed = 0
        self._truncations = 0
        self._preemptions = 0
        self._rejected = 0
        self._deadline_misses = 0
        self._tokens_out = 0
        self._ticks = 0
        self._occupancy_sum = 0

    # -- client API -----------------------------------------------------------

    @staticmethod
    def supports_prefill_buckets(cfg) -> bool:
        """Whether ``prefill_buckets`` is legal for this config: pad tokens
        must be causally invisible (full-attention families only — SSM
        state and sliding-window rings would absorb them)."""
        return (
            cfg.window is None
            and cfg.family in ("dense", "moe")
            and cfg.attention in ("gqa", "mla")
        )

    def _bucket(self, prompt_len: int) -> int:
        if self._buckets is None:
            return prompt_len
        for b in self._buckets:
            if b >= prompt_len:
                return b
        raise ValueError(f"prompt length {prompt_len} exceeds largest bucket {self._buckets[-1]}")

    def submit(
        self,
        prompt: Union[np.ndarray, Sequence[int]],
        max_new_tokens: int,
        *,
        deadline: Optional[float] = None,
    ) -> RequestHandle:
        """Queue one request; returns immediately with a handle.

        Raises :class:`QueueFull` when ``max_waiting`` requests are already
        queued (backpressure — retry later or shed load upstream).
        ``deadline`` (seconds) bounds time-to-first-token (module docs).
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if deadline is not None and deadline <= 0:
            raise ValueError("deadline must be positive seconds")
        pad = self._bucket(int(prompt.size))
        if pad >= self.kv.max_len:
            raise ValueError(
                f"padded prompt ({pad}) leaves no decode room in max_len={self.kv.max_len}"
            )
        rid = next(self._rid)
        handle = RequestHandle(
            rid, int(prompt.size), canceller=lambda: self._cancel(rid), deadline=deadline
        )
        req = GenRequest(prompt, int(max_new_tokens), deadline)
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if self._breaker_until:
                now = time.monotonic()
                if now < self._breaker_until:
                    self._rejected += 1
                    raise QueueFull(
                        "circuit breaker open for another "
                        f"{self._breaker_until - now:.2f}s "
                        f"({self._breaker_threshold} consecutive prefill failures)"
                    )
                # half-open: admit trial requests, but one more exhausted
                # failure re-trips immediately; a success fully closes it
                self._breaker_until = 0.0
                self._breaker_fails = self._breaker_threshold - 1
            if self._max_waiting is not None and self._nwaiting >= self._max_waiting:
                self._rejected += 1
                raise QueueFull(
                    f"admit queue full ({self._nwaiting} waiting >= max_waiting="
                    f"{self._max_waiting})"
                )
            p = _Pending(
                handle,
                req,
                None if deadline is None else handle.submit_t + deadline,
                next(self._order),
            )
            self._requests += 1
            self._pending_by_rid[rid] = p
            heapq.heappush(self._waiting, (p.key, p))
            self._nwaiting += 1
            self._pump_locked()
        return handle

    async def submit_async(
        self,
        prompt: Union[np.ndarray, Sequence[int]],
        max_new_tokens: int,
        *,
        deadline: Optional[float] = None,
    ) -> np.ndarray:
        """Asyncio-native submission: queue one request and ``await`` its
        generated ids without blocking the event loop (DESIGN.md §10 —
        completion transfers onto the loop via ``Future.__await__``)::

            tokens = await engine.submit_async(prompt, 32)

        For per-token delivery, ``submit`` + ``async for tok in handle``.
        Validation errors raise synchronously-in-await, generation errors
        resolve the awaitable, exactly like :meth:`submit` + ``result``.

        Cancelling the awaiting task propagates: a request that has not yet
        joined the decode batch is withdrawn (its queue entry, in-flight
        prefill result and any held pages are released) and its handle
        resolves with ``CancelledError`` — it never resolves with tokens.
        """
        import asyncio

        handle = self.submit(prompt, max_new_tokens, deadline=deadline)
        try:
            return await handle.future
        except asyncio.CancelledError:
            handle.cancel()  # best-effort: no-op once resident
            raise

    def generate(self, prompts, max_new_tokens, timeout: float = 300.0) -> list:
        """Submit many prompts and wait: returns per-prompt generated ids."""
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        handles = [self.submit(p, n) for p, n in zip(prompts, max_new_tokens)]
        return [h.result(timeout) for h in handles]

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has completed."""
        with self._idle:
            if not self._idle.wait_for(
                lambda: not (
                    self._nwaiting or self._inflight or self._joinq or self._active
                ),
                timeout,
            ):
                raise TimeoutError("engine did not drain within timeout")

    def close(self, drain: bool = True) -> None:
        # reject new submissions *before* draining: a submit landing in the
        # window between drain() returning and shutdown would be handed to a
        # pool about to abandon its queue, stranding the handle forever
        # (the close/prefill race — see tests/serve/test_engine.py)
        with self._lock:
            self._closed = True
        if drain:
            self.drain()
            # let the in-flight tick run wind down before pool teardown so
            # its condition task is not abandoned mid-cycle
            with self._idle:
                self._idle.wait_for(lambda: not self._tick_live, 60.0)
        if self.tracer is not None:
            tracer, self.tracer = self.tracer, None  # idempotent close
            self.pool.remove_observer(tracer)
            tracer.save(self._trace_path, num_workers=self.pool.num_threads)
        if self._own_pool:
            self.pool.close()

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc: object) -> None:
        self.close(drain=not any(exc))

    def stats(self) -> dict:
        """Engine + KV + pool statistics.

        ``pool`` now includes the §9 scheduler counters: ``parked``/
        ``wakeups`` expose how often engine workers actually slept between
        decode ticks versus being recruited by a targeted wakeup — the
        serving-side view of the spin-then-park protocol. The engine's
        prioritized tasks (decode > prefill) promote the pool's deques to
        banded mode on first use. §13 adds ``preemptions`` (page-pressure
        evictions to the admit queue), ``rejected`` (``QueueFull``
        backpressure), ``deadline_misses`` and the live ``waiting`` depth.
        """
        with self._lock:
            occ = self._occupancy_sum / self._ticks if self._ticks else 0.0
            plan = self._tick_graph.replay_plan
            return {
                "requests": self._requests,
                "completed": self._completed,
                "truncations": self._truncations,
                "preemptions": self._preemptions,
                "rejected": self._rejected,
                "deadline_misses": self._deadline_misses,
                "breaker_trips": self._breaker_trips,
                "waiting": self._nwaiting,
                "tokens_out": self._tokens_out,
                "ticks": self._ticks,
                "tick_replays": plan.replays if plan is not None else 0,
                "mean_occupancy": occ,
                "kv": self.kv.stats(),
                "pool": self.pool.stats(),
            }

    # -- scheduling internals ---------------------------------------------------

    def _cancel(self, rid: int) -> bool:
        """Canceller: True iff the request had not yet joined the batch.

        A cancelled request releases whatever it held (heap entry, in-flight
        prefill result, join-queue cache) — it never reaches a slot, so no
        pages are ever allocated for it.
        """
        with self._lock:
            p = self._pending_by_rid.get(rid)
            if p is None or p.cancelled:
                return False
            p.cancelled = True
            del self._pending_by_rid[rid]
            if p.stage == "waiting":
                self._nwaiting -= 1  # heap entry is skipped lazily at pump
            elif p.stage == "join":
                self._joinq = deque(e for e in self._joinq if e[0] is not p)
            # stage "prefill": _prefill_done sees p.cancelled on completion
            self._requests -= 1
            self._pump_locked()
            self._idle.notify_all()
            return True

    def _band(self, p: _Pending, now: float) -> float:
        """§13 deadline -> §9 priority band mapping (module docs)."""
        if p.tokens:
            return PREFILL_URGENT  # resumes block a mid-stream consumer
        if p.deadline is None or p.req.deadline is None:
            return PREFILL_PRIORITY
        frac = (p.deadline - now) / p.req.deadline  # headroom fraction
        if frac <= 0.25:
            return PREFILL_URGENT
        if frac <= 0.5:
            return PREFILL_SOON
        return PREFILL_PRIORITY

    def _pump_locked(self) -> None:
        """Admission: start prefills while capacity (+ lookahead) allows,
        in deadline order (earliest deadline first, then arrival)."""
        now = time.monotonic()
        while self._waiting and (
            self.kv.num_live + self._inflight + len(self._joinq)
            < self.kv.max_slots + self._lookahead
        ):
            _key, p = heapq.heappop(self._waiting)
            if p.cancelled:
                continue
            self._nwaiting -= 1
            p.stage = "prefill"
            self._inflight += 1
            name = ("resume" if p.tokens else "prefill") + f":{p.handle.rid}"
            t = Task(
                lambda p=p: self._prefill_one(p),
                name=name,
                priority=self._band(p, now),
                retry=self._prefill_retry,
            )
            t.propagate_errors = False
            t.on_done = lambda t, p=p: self._prefill_done(p, t)
            self.pool.submit(t)

    def _prefill_one(self, p: _Pending) -> None:
        """Prefill task *body*: deadline fail-fast + the pure jit compute.

        Exceptions raise out so the task's §14 retry policy sees them —
        transient failures re-run (every attempt re-checks the deadline),
        ``DeadlineExceeded`` never retries. All terminal bookkeeping lives
        in :meth:`_prefill_done` (the task's ``on_done``), which fires
        exactly once per task — never for a retried attempt.
        """
        handle, req = p.handle, p.req
        if not p.tokens and p.deadline is not None and time.monotonic() >= p.deadline:
            raise DeadlineExceeded(
                f"request {handle.rid} missed its {req.deadline:.3f}s deadline "
                "before prefill started"
            )
        if p.tokens:
            # resume a preempted sequence: re-prefill prompt + generated
            # prefix except the last token (it is the next decode feed).
            # Exact length, no bucketing — the length is feed_index and
            # is < max_len by the retire invariant.
            seq_toks = np.concatenate(
                [req.prompt, np.asarray(p.tokens[:-1], np.int32)]
            )
            plen = pad = int(seq_toks.size)
        else:
            seq_toks = req.prompt
            plen = int(req.prompt.size)
            pad = self._bucket(plen)
        toks = np.zeros((1, pad), np.int32)
        toks[0, :plen] = seq_toks
        logits, cache = self._prefill_jit(
            self.params,
            {"tokens": jnp.asarray(toks)},
            last_pos=jnp.asarray(plen - 1, jnp.int32),
        )
        p.joined = (cache, int(jnp.argmax(logits[0, -1])), pad)

    def _prefill_done(self, p: _Pending, task: Task) -> None:
        """Terminal prefill outcome (task ``on_done``): deliver failure or
        hand the result to the join queue, and feed the circuit breaker."""
        handle = p.handle
        exc = task.exception
        if exc is not None:
            with self._lock:
                self._inflight -= 1
                self._pending_by_rid.pop(handle.rid, None)
                if isinstance(exc, DeadlineExceeded):
                    self._deadline_misses += 1
                else:
                    # sustained non-deadline failure (model/runtime fault,
                    # retries exhausted): trip the breaker so submit()
                    # sheds load fast instead of queueing doomed requests
                    self._breaker_fails += 1
                    if self._breaker_fails >= self._breaker_threshold:
                        self._breaker_trips += 1
                        self._breaker_until = (
                            time.monotonic() + self._breaker_cooldown
                        )
                        self._breaker_fails = 0
                self._pump_locked()  # freed admission capacity: re-admit waiters
                self._idle.notify_all()
            if not handle.future.done():
                handle.future.set_exception(exc)
            return
        cache, first, pad = p.joined
        p.joined = None
        with self._lock:
            self._breaker_fails = 0  # a healthy prefill closes the streak
            self._inflight -= 1
            if p.cancelled:  # cancelled mid-prefill: drop the result
                self._pump_locked()
                self._idle.notify_all()
                return
            if self._broken is not None:  # engine died while we prefilled
                self._idle.notify_all()
                exc = self._broken
            else:
                p.stage = "join"
                self._joinq.append((p, cache, first, pad))
                self._schedule_tick_locked()
                return
        handle.future.set_exception(exc)

    def _schedule_tick_locked(self) -> None:
        """(Re)start the tick graph if no run is in flight.

        ``_tick_live`` flips False only in the run future's done callback,
        so a restart can never overlap a draining run (resetting a graph
        whose condition task is still completing would race its fan-out).
        """
        if self._tick_live or self._broken is not None:
            return
        self._tick_live = True
        # counted submission (the graph holds a condition) re-arms every
        # task; from the second restart on this is a §12 plan re-arm
        fut = self._exec.run(self._tick_graph)
        fut.add_done_callback(self._tick_run_done)

    def _tick_run_done(self, _fut: Future) -> None:
        """Run drained: mark idle, and restart if work raced the exit."""
        with self._lock:
            self._tick_live = False
            if self._active or self._joinq:
                self._schedule_tick_locked()
            else:
                self._idle.notify_all()  # close() waits for the run to land

    def _tick_more(self) -> int:
        """Condition body: loop (branch 0 -> tick) while work remains."""
        with self._lock:
            return 0 if self._broken is None and (self._active or self._joinq) else 1

    def _tick(self) -> None:
        try:
            self._tick_body()
        except BaseException as exc:  # noqa: BLE001 - fail every request and
            # brick the engine: the donated kv buffers may be invalid now
            with self._lock:
                self._broken = exc
                self._closed = True  # reject new submissions
                victims = [s.handle for s in self._active.values()]
                victims += [e[0].handle for e in self._joinq]
                victims += [
                    p.handle for _k, p in self._waiting if not p.cancelled
                ]
                for s in self._active.values():
                    self.kv.free(s.slot)
                self._active.clear()
                self._joinq.clear()
                self._waiting.clear()
                self._pending_by_rid.clear()
                self._nwaiting = 0
                self._idle.notify_all()
            # the condition task sees _broken and exits the cycle; the run
            # future's callback then clears _tick_live
            for h in victims:
                h.future.set_exception(exc)

    def _preempt_locked(self, victim: _Seq) -> None:
        """Page pressure: move the victim back to the admit queue.

        Its pages and slot are freed; the request re-enters the heap at its
        original (deadline, arrival) key carrying the generated prefix, to
        resume via an exact-length re-prefill. Work moves, never drops.
        """
        del self._active[victim.slot]
        self.kv.free(victim.slot)
        p = victim.p
        p.tokens = list(victim.tokens)
        p.stage = "waiting"
        self._pending_by_rid[p.handle.rid] = p
        heapq.heappush(self._waiting, (p.key, p))
        self._nwaiting += 1
        self._preemptions += 1

    def _tick_body(self) -> None:
        # 1. join freshly prefilled sequences into free slots (paged: the
        #    join claims pages for the prefilled prompt only)
        with self._lock:
            joins = []
            while self._joinq:
                p, cache, first, pad = self._joinq[0]
                slot = self.kv.alloc(self.kv.pages_for(pad))
                if slot is None:  # lookahead prefills wait for slot/pages
                    break
                self._joinq.popleft()
                self._pending_by_rid.pop(p.handle.rid, None)
                p.stage = "active"
                if p.tokens:  # resumed sequence: prefix already delivered
                    seq = _Seq(
                        p,
                        list(p.tokens),
                        p.handle.prompt_len + len(p.tokens) - 1,
                        p.req.max_new_tokens - len(p.tokens),
                        slot,
                    )
                else:
                    seq = _Seq(p, [first], p.handle.prompt_len, p.req.max_new_tokens - 1, slot)
                    self._tokens_out += 1  # the prefill-produced first token
                    p.handle._push(first)
                self._active[slot] = seq
                joins.append((slot, cache, pad))
        for slot, cache, pad in joins:
            self.kv.write(slot, cache, pad)  # tick chain serializes buffers

        retired: list = []
        with self._lock:
            self._retire_locked(retired)  # max_new_tokens == 1 finishes at join
            # 1b. back every lane's write position with a physical page;
            #     on page pressure preempt the youngest resident (oldest
            #     sequences grow first, so the victim order is stable)
            for seq in sorted(self._active.values(), key=lambda s: s.p.order):
                while seq.slot in self._active and not self.kv.grow_to(
                    seq.slot, seq.feed_index + 1
                ):
                    victim = max(self._active.values(), key=lambda s: s.p.order)
                    self._preempt_locked(victim)
            if not self._active:
                # nothing to decode this pass; the condition task loops if
                # the join queue refilled, else the cycle drains
                self._pump_locked()
                self._idle.notify_all()
                self._resolve(retired)
                return
            tok_np = np.zeros((self.kv.max_slots, 1, 1), np.int32)
            idx_np = np.zeros((self.kv.max_slots,), np.int32)
            feeds: dict[int, int] = {}
            for slot, seq in self._active.items():
                tok_np[slot, 0, 0] = seq.tokens[-1]
                idx_np[slot] = seq.feed_index
                feeds[slot] = seq.feed_index
            self._ticks += 1
            self._occupancy_sum += len(self._active)

        # 2. one decode step over the padded slot batch (outside the lock)
        if self._paged:
            tables, dest = self.kv.tick_inputs(feeds)
            next_toks, self.kv.pools = self._tick_jit(
                self.params,
                jnp.asarray(tok_np),
                self.kv.pools,
                jnp.asarray(tables),
                jnp.asarray(dest),
                jnp.asarray(idx_np),
            )
        else:
            next_toks, self.kv.buffers = self._tick_jit(
                self.params, jnp.asarray(tok_np), self.kv.buffers, jnp.asarray(idx_np)
            )
        next_np = np.asarray(next_toks)  # (slots, 1)

        # 3. apply results, retire finished/evicted, admit more work
        pushes = []
        with self._lock:
            for slot, seq in list(self._active.items()):
                tok = int(next_np[slot, 0])
                seq.tokens.append(tok)
                seq.feed_index += 1
                seq.remaining -= 1
                self._tokens_out += 1
                pushes.append((seq.handle, tok))
            self._retire_locked(retired)
            self._pump_locked()
            self._idle.notify_all()  # the condition task decides the loop
        for handle, tok in pushes:
            handle._push(tok)
        self._resolve(retired)

    def _retire_locked(self, retired: list) -> None:
        for slot, seq in list(self._active.items()):
            finished = seq.remaining <= 0
            evicted = not finished and seq.feed_index >= self.kv.max_len
            if finished or evicted:
                del self._active[slot]
                if evicted:
                    self.kv.evict(slot)
                    self._truncations += 1
                else:
                    self.kv.free(slot)
                self._completed += 1
                retired.append((seq, evicted))

    def _resolve(self, retired: list) -> None:
        for seq, evicted in retired:
            seq.handle.truncated = evicted
            seq.handle.future.set_result(np.asarray(seq.tokens, np.int32))
