"""Continuous-batching inference engine on the work-stealing pool.

The serving path is expressed as prioritized tasks on the paper's thread
pool (DESIGN.md §7):

* **prefill** tasks run at LOW priority — they are pure (compute a batch-1
  cache + first token, touch no shared buffers) and arbitrarily parallel, so
  they soak up idle workers without ever delaying a decode step;
* **decode ticks** run at HIGH priority — the same B-before-F idea that
  makes the schedule simulator reproduce 1F1B: drain work that frees
  resources (finishing sequences release cache slots) before admitting more.

The engine batches at *iteration level*: between two decode ticks it joins
freshly prefilled sequences into free cache slots and retires finished ones,
so the padded decode batch tracks live traffic instead of a static batch
running to the longest member. One tick is one jitted
``vmap(model.decode_step)`` over the slot axis with a per-slot write index —
sequences of different lengths share one decode computation.

Ticks form a **condition-cycle graph** (DESIGN.md §10) submitted through
the :class:`~repro.core.Executor` facade:

    entry -> decode-tick -> more? (condition)
                 ^______________|   (weak back-edge while work remains)

The loop serializes all mutation of the shared slot buffers exactly as the
old self-rescheduling chain did, but the steady-state hop from tick to
tick is a weak-edge trigger inside a worker — no per-tick task allocation,
no external submission, no inbox lock. The graph is (re)started only when
work arrives on an idle engine, handed off through the run future's done
callback so a restart can never overlap a draining run. Admission and
queue bookkeeping stay lock-protected and may run from any thread.

Because the tick graph never changes shape, every restart after the first
dispatches from its captured :class:`~repro.core.ReplayPlan` (DESIGN.md
§12): the ``[decode-tick, more?]`` pair runs as one fused segment whose
weak back-edge loops without re-walking the live graph, and re-starting a
drained run costs a plan re-arm instead of a full reset + re-wire.
``stats()["tick_replays"]`` counts how many restarts took the replay path.

``submit_async`` rides the same facade's asyncio bridge: an async server
can ``tokens = await engine.submit_async(prompt, n)`` without blocking its
event loop.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChromeTraceObserver, Executor, Future, Task, TaskGraph, ThreadPool

from .kv import SlotKVCache

__all__ = ["ServeEngine", "GenRequest", "RequestHandle", "PREFILL_PRIORITY", "DECODE_PRIORITY"]

PREFILL_PRIORITY = -1.0
DECODE_PRIORITY = 1.0


@dataclass(frozen=True)
class GenRequest:
    """One generation request: prompt token ids + greedy-decode budget."""

    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int


class RequestHandle:
    """Client-side handle: a cancellable future over the generated tokens.

    ``result()`` returns the generated token ids as a 1-D int32 array (the
    prompt is not echoed). ``cancel()`` succeeds only while the request is
    still queued (cooperative semantics — in-flight work runs to
    completion). ``truncated`` is set when the sequence was evicted at cache
    capacity before reaching its token budget.
    """

    def __init__(self, rid: int, prompt_len: int, canceller) -> None:
        self.rid = rid
        self.prompt_len = prompt_len
        self.truncated = False
        self.future = Future(canceller=canceller)

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        return self.future.result(timeout)

    def cancel(self) -> bool:
        return self.future.cancel()

    def done(self) -> bool:
        return self.future.done()


class _Seq:
    """A live sequence occupying one cache slot."""

    __slots__ = ("handle", "tokens", "feed_index", "remaining", "slot")

    def __init__(self, handle: RequestHandle, first_token: int, prompt_len: int, budget: int, slot: int) -> None:
        self.handle = handle
        self.tokens = [first_token]
        self.feed_index = prompt_len  # position of the token fed next tick
        self.remaining = budget - 1  # first token came from prefill
        self.slot = slot


class ServeEngine:
    """Continuous-batching greedy-decode engine.

    Parameters
    ----------
    model, params:
        A ``repro.models.Model`` and its parameter tree. Encoder-decoder and
        VLM families are not supported (their prefill inputs are not plain
        token prompts).
    max_slots:
        Decode batch width = number of resident sequences.
    max_len:
        Per-slot cache capacity (prompt + generated). Sequences reaching it
        are evicted (``handle.truncated``).
    pool:
        Shared :class:`ThreadPool`; the engine owns a 2-worker pool if None.
    prefill_buckets:
        Optional ascending prompt-length buckets. Prompts are right-padded to
        the smallest fitting bucket so prefill compiles once per bucket
        instead of once per length. Only valid for full-attention families
        (pad tokens are causally invisible and masked by ``valid_len`` during
        decode); SSM/hybrid state and sliding-window rings would absorb the
        pad tokens, so bucketing is rejected there.
    prefill_lookahead:
        How many prefills may run/wait beyond free slot capacity (default:
        ``max_slots``). Speculative prefills keep the join queue warm so a
        retiring sequence is replaced at the very next tick; each waiting
        join holds one batch-1 cache of bucket length, which bounds the
        extra memory.
    trace_path:
        When set, a :class:`~repro.core.ChromeTraceObserver` is attached to
        the pool for the engine's lifetime and the trace (every prefill
        task, decode tick and steal, per worker lane) is written there on
        ``close()`` — load it in ``chrome://tracing``. Exposed as
        ``self.tracer`` for mid-run snapshots (``tracer.to_trace()``). On a
        shared pool the trace includes the other users' tasks too, which is
        usually what you want when diagnosing interference.
    """

    def __init__(
        self,
        model,
        params,
        *,
        max_slots: int = 8,
        max_len: int = 256,
        pool: Optional[ThreadPool] = None,
        prefill_buckets: Optional[Sequence[int]] = None,
        prefill_lookahead: Optional[int] = None,
        trace_path: Optional[str] = None,
    ) -> None:
        cfg = model.cfg
        if cfg.is_encdec or cfg.family == "vlm":
            raise NotImplementedError(
                f"ServeEngine supports text-prompt families only, got {cfg.family!r}"
            )
        if prefill_buckets is not None and not self.supports_prefill_buckets(cfg):
            raise ValueError(
                "prefill_buckets requires a full-attention family (no SSM state, "
                f"no sliding window); {cfg.name} would absorb pad tokens"
            )
        self.model = model
        self.params = params
        self.kv = SlotKVCache(model, max_slots, max_len)
        self.pool = pool or ThreadPool(2, name="serve")
        self._own_pool = pool is None
        self._trace_path = trace_path
        self.tracer: Optional[ChromeTraceObserver] = None
        if trace_path is not None:
            self.tracer = ChromeTraceObserver()
            self.pool.add_observer(self.tracer)
        self._buckets = tuple(sorted(prefill_buckets)) if prefill_buckets else None
        self._lookahead = max_slots if prefill_lookahead is None else prefill_lookahead
        self._prefill_jit = jax.jit(model.prefill)

        def _step(p, tok, cache, idx):
            logits, cache = model.decode_step(p, tok, cache, idx)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

        self._tick_jit = jax.jit(
            jax.vmap(_step, in_axes=(None, 0, 0, 0)), donate_argnums=(2,)
        )

        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._waiting: deque = deque()  # (handle, GenRequest)
        self._inflight = 0  # prefill tasks in flight
        self._joinq: deque = deque()  # (handle, req, cache, first_token, pad_len)
        self._active: dict[int, _Seq] = {}
        # -- the condition-cycle tick graph (module docs): built once,
        # looped by its weak back-edge, restarted only from idle.
        self._exec = Executor(pool=self.pool)
        tg = TaskGraph("serve-tick")
        entry = tg.add(None, name="tick-entry", priority=DECODE_PRIORITY)
        tick = tg.add(self._tick, name="decode-tick", priority=DECODE_PRIORITY)
        tick.after(entry)
        more = tg.add(
            self._tick_more, name="more?", kind="condition", priority=DECODE_PRIORITY
        )
        more.after(tick)
        more.precede(tick)  # branch 0: weak back-edge -> next tick
        for t in tg.tasks:
            t.propagate_errors = False
        self._tick_graph = tg
        self._tick_live = False  # a run of the tick graph is in flight
        self._closed = False
        self._broken: Optional[BaseException] = None
        self._rid = itertools.count()
        self._requests = 0
        self._completed = 0
        self._truncations = 0
        self._tokens_out = 0
        self._ticks = 0
        self._occupancy_sum = 0

    # -- client API -----------------------------------------------------------

    @staticmethod
    def supports_prefill_buckets(cfg) -> bool:
        """Whether ``prefill_buckets`` is legal for this config: pad tokens
        must be causally invisible (full-attention families only — SSM
        state and sliding-window rings would absorb them)."""
        return (
            cfg.window is None
            and cfg.family in ("dense", "moe")
            and cfg.attention in ("gqa", "mla")
        )

    def _bucket(self, prompt_len: int) -> int:
        if self._buckets is None:
            return prompt_len
        for b in self._buckets:
            if b >= prompt_len:
                return b
        raise ValueError(f"prompt length {prompt_len} exceeds largest bucket {self._buckets[-1]}")

    def submit(self, prompt: Union[np.ndarray, Sequence[int]], max_new_tokens: int) -> RequestHandle:
        """Queue one request; returns immediately with a handle."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        pad = self._bucket(int(prompt.size))
        if pad >= self.kv.max_len:
            raise ValueError(
                f"padded prompt ({pad}) leaves no decode room in max_len={self.kv.max_len}"
            )
        rid = next(self._rid)
        handle = RequestHandle(rid, int(prompt.size), canceller=lambda: self._cancel(rid))
        req = GenRequest(prompt, int(max_new_tokens))
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            self._requests += 1
            self._waiting.append((handle, req))
            self._pump_locked()
        return handle

    async def submit_async(
        self, prompt: Union[np.ndarray, Sequence[int]], max_new_tokens: int
    ) -> np.ndarray:
        """Asyncio-native submission: queue one request and ``await`` its
        generated ids without blocking the event loop (DESIGN.md §10 —
        completion transfers onto the loop via ``Future.__await__``)::

            tokens = await engine.submit_async(prompt, 32)

        Validation errors raise synchronously-in-await, generation errors
        resolve the awaitable, exactly like :meth:`submit` + ``result``.
        """
        handle = self.submit(prompt, max_new_tokens)
        return await handle.future

    def generate(self, prompts, max_new_tokens, timeout: float = 300.0) -> list:
        """Submit many prompts and wait: returns per-prompt generated ids."""
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        handles = [self.submit(p, n) for p, n in zip(prompts, max_new_tokens)]
        return [h.result(timeout) for h in handles]

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has completed."""
        with self._idle:
            if not self._idle.wait_for(
                lambda: not (self._waiting or self._inflight or self._joinq or self._active),
                timeout,
            ):
                raise TimeoutError("engine did not drain within timeout")

    def close(self, drain: bool = True) -> None:
        if drain:
            self.drain()
        with self._lock:
            self._closed = True
        if self.tracer is not None:
            tracer, self.tracer = self.tracer, None  # idempotent close
            self.pool.remove_observer(tracer)
            tracer.save(self._trace_path, num_workers=self.pool.num_threads)
        if self._own_pool:
            self.pool.close()

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc: object) -> None:
        self.close(drain=not any(exc))

    def stats(self) -> dict:
        """Engine + KV + pool statistics.

        ``pool`` now includes the §9 scheduler counters: ``parked``/
        ``wakeups`` expose how often engine workers actually slept between
        decode ticks versus being recruited by a targeted wakeup — the
        serving-side view of the spin-then-park protocol. The engine's
        prioritized tasks (decode > prefill) promote the pool's deques to
        banded mode on first use; everything else in the engine is
        unchanged on the §9 internals.
        """
        with self._lock:
            occ = self._occupancy_sum / self._ticks if self._ticks else 0.0
            plan = self._tick_graph.replay_plan
            return {
                "requests": self._requests,
                "completed": self._completed,
                "truncations": self._truncations,
                "tokens_out": self._tokens_out,
                "ticks": self._ticks,
                "tick_replays": plan.replays if plan is not None else 0,
                "mean_occupancy": occ,
                "kv": self.kv.stats(),
                "pool": self.pool.stats(),
            }

    # -- scheduling internals ---------------------------------------------------

    def _cancel(self, rid: int) -> bool:
        with self._lock:
            for i, (handle, _req) in enumerate(self._waiting):
                if handle.rid == rid:
                    del self._waiting[i]
                    self._requests -= 1
                    self._idle.notify_all()
                    return True
        return False

    def _pump_locked(self) -> None:
        """Admission: start prefills while capacity (+ lookahead) allows."""
        while self._waiting and (
            self.kv.num_live + self._inflight + len(self._joinq)
            < self.kv.max_slots + self._lookahead
        ):
            handle, req = self._waiting.popleft()
            self._inflight += 1
            t = Task(
                lambda h=handle, r=req: self._prefill_one(h, r),
                name=f"prefill:{handle.rid}",
                priority=PREFILL_PRIORITY,
            )
            t.propagate_errors = False
            self.pool.submit(t)

    def _prefill_one(self, handle: RequestHandle, req: GenRequest) -> None:
        try:
            plen = int(req.prompt.size)
            pad = self._bucket(plen)
            toks = np.zeros((1, pad), np.int32)
            toks[0, :plen] = req.prompt
            logits, cache = self._prefill_jit(
                self.params,
                {"tokens": jnp.asarray(toks)},
                last_pos=jnp.asarray(plen - 1, jnp.int32),
            )
            first = int(jnp.argmax(logits[0, -1]))
        except BaseException as exc:  # noqa: BLE001 - delivered via the handle
            with self._lock:
                self._inflight -= 1
                self._pump_locked()  # freed admission capacity: re-admit waiters
                self._idle.notify_all()
            handle.future.set_exception(exc)
            return
        with self._lock:
            self._inflight -= 1
            if self._broken is not None:  # engine died while we prefilled
                self._idle.notify_all()
                exc = self._broken
            else:
                self._joinq.append((handle, req, cache, first, pad))
                self._schedule_tick_locked()
                return
        handle.future.set_exception(exc)

    def _schedule_tick_locked(self) -> None:
        """(Re)start the tick graph if no run is in flight.

        ``_tick_live`` flips False only in the run future's done callback,
        so a restart can never overlap a draining run (resetting a graph
        whose condition task is still completing would race its fan-out).
        """
        if self._tick_live or self._broken is not None:
            return
        self._tick_live = True
        # counted submission (the graph holds a condition) re-arms every
        # task; from the second restart on this is a §12 plan re-arm
        fut = self._exec.run(self._tick_graph)
        fut.add_done_callback(self._tick_run_done)

    def _tick_run_done(self, _fut: Future) -> None:
        """Run drained: mark idle, and restart if work raced the exit."""
        with self._lock:
            self._tick_live = False
            if self._active or self._joinq:
                self._schedule_tick_locked()

    def _tick_more(self) -> int:
        """Condition body: loop (branch 0 -> tick) while work remains."""
        with self._lock:
            return 0 if self._broken is None and (self._active or self._joinq) else 1

    def _tick(self) -> None:
        try:
            self._tick_body()
        except BaseException as exc:  # noqa: BLE001 - fail every request and
            # brick the engine: the donated kv buffers may be invalid now
            with self._lock:
                self._broken = exc
                self._closed = True  # reject new submissions
                victims = [s.handle for s in self._active.values()]
                victims += [h for h, *_ in self._joinq]
                victims += [h for h, _req in self._waiting]
                for s in self._active.values():
                    self.kv.free(s.slot)
                self._active.clear()
                self._joinq.clear()
                self._waiting.clear()
                self._idle.notify_all()
            # the condition task sees _broken and exits the cycle; the run
            # future's callback then clears _tick_live
            for h in victims:
                h.future.set_exception(exc)

    def _tick_body(self) -> None:
        # 1. join freshly prefilled sequences into free slots
        with self._lock:
            joins = []
            while self._joinq:
                slot = self.kv.alloc()
                if slot is None:  # lookahead prefills wait for a free slot
                    break
                handle, req, cache, first, pad = self._joinq.popleft()
                seq = _Seq(handle, first, handle.prompt_len, req.max_new_tokens, slot)
                self._active[slot] = seq
                self._tokens_out += 1  # the prefill-produced first token
                joins.append((slot, cache, pad))
        for slot, cache, pad in joins:
            self.kv.write(slot, cache, pad)  # tick chain serializes buffers

        retired: list = []
        with self._lock:
            self._retire_locked(retired)  # max_new_tokens == 1 finishes at join
            if not self._active:
                # nothing to decode this pass; the condition task loops if
                # the join queue refilled, else the cycle drains
                self._pump_locked()
                self._idle.notify_all()
                self._resolve(retired)
                return
            tok_np = np.zeros((self.kv.max_slots, 1, 1), np.int32)
            idx_np = np.zeros((self.kv.max_slots,), np.int32)
            for slot, seq in self._active.items():
                tok_np[slot, 0, 0] = seq.tokens[-1]
                idx_np[slot] = seq.feed_index
            self._ticks += 1
            self._occupancy_sum += len(self._active)

        # 2. one decode step over the padded slot batch (outside the lock)
        next_toks, self.kv.buffers = self._tick_jit(
            self.params, jnp.asarray(tok_np), self.kv.buffers, jnp.asarray(idx_np)
        )
        next_np = np.asarray(next_toks)  # (slots, 1)

        # 3. apply results, retire finished/evicted, admit more work
        with self._lock:
            for slot, seq in list(self._active.items()):
                seq.tokens.append(int(next_np[slot, 0]))
                seq.feed_index += 1
                seq.remaining -= 1
                self._tokens_out += 1
            self._retire_locked(retired)
            self._pump_locked()
            self._idle.notify_all()  # the condition task decides the loop
        self._resolve(retired)

    def _retire_locked(self, retired: list) -> None:
        for slot, seq in list(self._active.items()):
            finished = seq.remaining <= 0
            evicted = not finished and seq.feed_index >= self.kv.max_len
            if finished or evicted:
                del self._active[slot]
                if evicted:
                    self.kv.evict(slot)
                    self._truncations += 1
                else:
                    self.kv.free(slot)
                self._completed += 1
                retired.append((seq, evicted))

    def _resolve(self, retired: list) -> None:
        for seq, evicted in retired:
            seq.handle.truncated = evicted
            seq.handle.future.set_result(np.asarray(seq.tokens, np.int32))
