"""Slot-based KV-cache pool for continuous batching (DESIGN.md §7).

The per-family cache-layout knowledge that used to live inside
``models/lm.py`` (``extend_caches``) is concentrated here: how each cache
kind grows along its sequence axis, and how the kinds that *don't* grow
(sliding-window rings, SSM recurrent state, static cross-attention K/V)
pass through. ``models.lm.extend_caches`` now delegates to
:func:`pad_caches_to`.

Cache kinds, by leaf signature:

* ``{"k", "v"}``            GQA append cache — pad along the seq axis.
* ``{"k", "v", "pos"}``     sliding-window ring — fixed modulus ``W``; a
                            smaller prefill ring is re-laid-out into the
                            target ring by the ``slot = pos % W`` invariant.
* ``{"ckv", "krope"}``      MLA compressed latents — pad along seq.
* anything else             SSM state / conv stream / static encoder K/V —
                            fixed size, pass through.

:class:`SlotKVCache` pools these per-sequence caches: one big buffer tree
whose leading axis is the *slot* index, each slot holding a batch-1 cache of
length ``max_len``. Sequences of different lengths then share one padded
decode batch — the engine vmaps the model's single-token ``decode_step``
over the slot axis with a per-slot write index.
"""
from __future__ import annotations

import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# per-family cache walks (pure, traceable)
# ---------------------------------------------------------------------------


def _is_gqa(node: Any) -> bool:
    return isinstance(node, dict) and "k" in node and "v" in node


def _is_mla(node: Any) -> bool:
    return isinstance(node, dict) and "ckv" in node


def _pad_seq(arr: jax.Array, axis: int, extra: int) -> jax.Array:
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, extra)
    return jnp.pad(arr, pad)


def _scatter_seq(dst: jax.Array, src: jax.Array, idx: jax.Array, axis: int) -> jax.Array:
    """``dst`` with ``src`` scattered at positions ``idx`` along ``axis``."""
    dst_m = jnp.moveaxis(dst, axis, 0)
    src_m = jnp.moveaxis(src, axis, 0)
    return jnp.moveaxis(dst_m.at[idx].set(src_m), 0, axis)


def _grow_ring(node: dict, target_w: int) -> dict:
    """Re-lay a ring cache of modulus ``W0`` into modulus ``target_w``.

    The ring invariant is "absolute position p lives at slot p % W". A
    prefill over a prompt shorter than the window returns a ring of modulus
    ``W0 = S < W``; re-scatter each entry to ``pos % W`` and mark empty
    slots with pos = -1 (masked by the decode bias). The stored positions
    are a contiguous run of length W0 <= W, hence distinct mod W.
    """
    pos = node["pos"]
    w0 = pos.shape[-1]
    if w0 == target_w:
        return node
    if w0 > target_w:
        raise ValueError(f"ring cache modulus {w0} exceeds slot capacity {target_w}")
    # positions are identical across any stacked (layers) prefix
    flat_pos = pos.reshape(-1, w0)[0].astype(jnp.int32)
    idx = jnp.mod(flat_pos, target_w)
    out = {}
    for key in ("k", "v"):
        arr = node[key]
        ax = arr.ndim - 3  # (..., B, W, KV, Dh)
        dst = jnp.zeros(arr.shape[:ax] + (target_w,) + arr.shape[ax + 1 :], arr.dtype)
        out[key] = _scatter_seq(dst, arr, idx, ax)
    dst_pos = jnp.full(pos.shape[:-1] + (target_w,), -1, pos.dtype)
    out["pos"] = _scatter_seq(dst_pos, pos, idx, pos.ndim - 1)
    return out


def pad_caches_to(caches: dict, extra: int, *, ring_w: Optional[int] = None) -> dict:
    """Grow every growable cache leaf by ``extra`` positions.

    Attention K/V and MLA latents are zero-padded along their sequence axis;
    ring buffers are re-laid to modulus ``ring_w`` when given (else passed
    through); SSM state, conv streams and static cross-attention K/V pass
    through untouched. Handles scan-stacked leaves (leading layers dim).
    """

    def walk(node):
        if _is_gqa(node):
            if "pos" in node:  # ring buffer: fixed modulus
                return _grow_ring(node, ring_w) if ring_w is not None else node
            ax = node["k"].ndim - 3  # (..., B, S, KV, Dh): seq axis
            return {
                "k": _pad_seq(node["k"], ax, extra),
                "v": _pad_seq(node["v"], ax, extra),
            }
        if _is_mla(node):
            ax = node["ckv"].ndim - 2  # (..., B, S, L): seq axis
            return {
                "ckv": _pad_seq(node["ckv"], ax, extra),
                "krope": _pad_seq(node["krope"], ax, extra),
            }
        if isinstance(node, dict):
            # cross-attn caches hold static encoder K/V: never grown
            return {k: (v if k == "cross" else walk(v)) for k, v in node.items()}
        return node  # SSM state / conv stream: fixed size

    return walk(caches)


def _ring_modulus(node: Any, acc: list) -> None:
    if _is_gqa(node) and "pos" in node:
        acc.append(node["pos"].shape[-1])
    elif isinstance(node, dict):
        for v in node.values():
            _ring_modulus(v, acc)


def ring_modulus(caches: dict) -> Optional[int]:
    """Modulus of the sliding-window ring leaves, or None if there are none."""
    acc: list = []
    _ring_modulus(caches, acc)
    return acc[0] if acc else None


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------


class SlotKVCache:
    """A pool of ``max_slots`` per-sequence caches sharing one buffer tree.

    Every leaf of ``buffers`` has shape ``(max_slots, *leaf_b1)`` where
    ``leaf_b1`` is the model's batch-1 cache shape at length ``max_len``
    (from ``model.cache_shapes(1, max_len)``). Allocation is a free-list;
    ``write`` pads a freshly prefilled batch-1 cache out to ``max_len`` and
    overwrites one slot in a single donated jit (no host round-trip).

    Thread safety: alloc/free/evict are lock-protected; ``write`` and the
    engine's decode tick mutate ``buffers`` and must be serialized by the
    caller (the engine's tick chain does this).
    """

    def __init__(self, model, max_slots: int, max_len: int) -> None:
        if max_slots < 1 or max_len < 1:
            raise ValueError("max_slots and max_len must be >= 1")
        self.max_slots = max_slots
        self.max_len = max_len
        self._slot_shapes = model.cache_shapes(1, max_len)
        self.buffers = jax.tree.map(
            lambda s: jnp.zeros((max_slots, *s.shape), s.dtype), self._slot_shapes
        )
        rings: list = []
        _ring_modulus(self._slot_shapes, rings)
        self._ring_w = rings[0] if rings else None
        self._lock = threading.Lock()
        self._free = list(range(max_slots - 1, -1, -1))  # pop() -> lowest slot
        self._live: set[int] = set()
        self.allocs = 0
        self.evictions = 0
        self.peak_live = 0

        def _write(buffers, new_cache, slot, prefill_len):
            padded = pad_caches_to(
                new_cache, self.max_len - prefill_len, ring_w=self._ring_w
            )
            return jax.tree.map(lambda b, n: b.at[slot].set(n), buffers, padded)

        # one jit; retraces per distinct prefill length (bucketed upstream)
        self._write_jit = jax.jit(_write, donate_argnums=(0,), static_argnums=(3,))

    # -- slot lifecycle -------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return len(self._live)

    def alloc(self) -> Optional[int]:
        """Claim a slot, or None when the pool is exhausted."""
        with self._lock:
            if not self._free:
                return None
            slot = self._free.pop()
            self._live.add(slot)
            self.allocs += 1
            self.peak_live = max(self.peak_live, len(self._live))
            return slot

    def free(self, slot: int) -> None:
        """Return a slot to the pool (retired sequence)."""
        with self._lock:
            if slot not in self._live:
                raise ValueError(f"slot {slot} is not live")
            self._live.remove(slot)
            self._free.append(slot)

    def evict(self, slot: int) -> None:
        """Forcibly free a live slot (capacity eviction); counted separately."""
        self.free(slot)
        with self._lock:
            self.evictions += 1

    # -- data movement --------------------------------------------------------

    def write(self, slot: int, cache: dict, prefill_len: int) -> None:
        """Install a batch-1 prefill cache (length ``prefill_len``) into ``slot``.

        Caller must hold the engine's tick serialization (buffers are
        donated). The cache is padded/re-laid out to ``max_len`` on device.
        """
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not live")
        if prefill_len > self.max_len:
            raise ValueError(f"prefill length {prefill_len} exceeds max_len {self.max_len}")
        self.buffers = self._write_jit(
            self.buffers, cache, jnp.asarray(slot, jnp.int32), prefill_len
        )

    def read_slot(self, slot: int) -> dict:
        """The batch-1 cache tree currently stored in ``slot`` (for tests)."""
        return jax.tree.map(lambda b: b[slot], self.buffers)

    def stats(self) -> dict:
        with self._lock:
            return {
                "max_slots": self.max_slots,
                "live": len(self._live),
                "free": len(self._free),
                "allocs": self.allocs,
                "evictions": self.evictions,
                "peak_live": self.peak_live,
            }
