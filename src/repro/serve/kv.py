"""Slot-based KV-cache pool for continuous batching (DESIGN.md §7).

The per-family cache-layout knowledge that used to live inside
``models/lm.py`` (``extend_caches``) is concentrated here: how each cache
kind grows along its sequence axis, and how the kinds that *don't* grow
(sliding-window rings, SSM recurrent state, static cross-attention K/V)
pass through. ``models.lm.extend_caches`` now delegates to
:func:`pad_caches_to`.

Cache kinds, by leaf signature:

* ``{"k", "v"}``            GQA append cache — pad along the seq axis.
* ``{"k", "v", "pos"}``     sliding-window ring — fixed modulus ``W``; a
                            smaller prefill ring is re-laid-out into the
                            target ring by the ``slot = pos % W`` invariant.
* ``{"ckv", "krope"}``      MLA compressed latents — pad along seq.
* anything else             SSM state / conv stream / static encoder K/V —
                            fixed size, pass through.

:class:`SlotKVCache` pools these per-sequence caches: one big buffer tree
whose leading axis is the *slot* index, each slot holding a batch-1 cache of
length ``max_len``. Sequences of different lengths then share one padded
decode batch — the engine vmaps the model's single-token ``decode_step``
over the slot axis with a per-slot write index.

:class:`PagedKVCache` (DESIGN.md §13) replaces the flat per-slot layout
with a pool of fixed-size *pages*: every growable leaf (GQA append K/V, MLA
latents) is stored as ``(num_pages, ..., page_size, ...)`` with a free-list
of physical page ids and a per-slot page table; fixed-size leaves (SSM
state, sliding-window rings, static encoder K/V) stay slot-indexed exactly
as in the flat cache. Prefill installs only the pages a prompt actually
covers (O(pages touched), not O(max_len)), growth is appending one page id
to a table row, and the decode tick reads through a gather that
reassembles each slot's logical cache from its pages — bit-identical to
the flat layout because unmapped table entries point at a reserved
always-zero page.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# per-family cache walks (pure, traceable)
# ---------------------------------------------------------------------------


def _is_gqa(node: Any) -> bool:
    return isinstance(node, dict) and "k" in node and "v" in node


def _is_mla(node: Any) -> bool:
    return isinstance(node, dict) and "ckv" in node


def _pad_seq(arr: jax.Array, axis: int, extra: int) -> jax.Array:
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, extra)
    return jnp.pad(arr, pad)


def _scatter_seq(dst: jax.Array, src: jax.Array, idx: jax.Array, axis: int) -> jax.Array:
    """``dst`` with ``src`` scattered at positions ``idx`` along ``axis``."""
    dst_m = jnp.moveaxis(dst, axis, 0)
    src_m = jnp.moveaxis(src, axis, 0)
    return jnp.moveaxis(dst_m.at[idx].set(src_m), 0, axis)


def _grow_ring(node: dict, target_w: int) -> dict:
    """Re-lay a ring cache of modulus ``W0`` into modulus ``target_w``.

    The ring invariant is "absolute position p lives at slot p % W". A
    prefill over a prompt shorter than the window returns a ring of modulus
    ``W0 = S < W``; re-scatter each entry to ``pos % W`` and mark empty
    slots with pos = -1 (masked by the decode bias). The stored positions
    are a contiguous run of length W0 <= W, hence distinct mod W.
    """
    pos = node["pos"]
    w0 = pos.shape[-1]
    if w0 == target_w:
        return node
    if w0 > target_w:
        raise ValueError(f"ring cache modulus {w0} exceeds slot capacity {target_w}")
    # positions are identical across any stacked (layers) prefix
    flat_pos = pos.reshape(-1, w0)[0].astype(jnp.int32)
    idx = jnp.mod(flat_pos, target_w)
    out = {}
    for key in ("k", "v"):
        arr = node[key]
        ax = arr.ndim - 3  # (..., B, W, KV, Dh)
        dst = jnp.zeros(arr.shape[:ax] + (target_w,) + arr.shape[ax + 1 :], arr.dtype)
        out[key] = _scatter_seq(dst, arr, idx, ax)
    dst_pos = jnp.full(pos.shape[:-1] + (target_w,), -1, pos.dtype)
    out["pos"] = _scatter_seq(dst_pos, pos, idx, pos.ndim - 1)
    return out


def pad_caches_to(caches: dict, extra: int, *, ring_w: Optional[int] = None) -> dict:
    """Grow every growable cache leaf by ``extra`` positions.

    Attention K/V and MLA latents are zero-padded along their sequence axis;
    ring buffers are re-laid to modulus ``ring_w`` when given (else passed
    through); SSM state, conv streams and static cross-attention K/V pass
    through untouched. Handles scan-stacked leaves (leading layers dim).
    """

    def walk(node):
        if _is_gqa(node):
            if "pos" in node:  # ring buffer: fixed modulus
                return _grow_ring(node, ring_w) if ring_w is not None else node
            ax = node["k"].ndim - 3  # (..., B, S, KV, Dh): seq axis
            return {
                "k": _pad_seq(node["k"], ax, extra),
                "v": _pad_seq(node["v"], ax, extra),
            }
        if _is_mla(node):
            ax = node["ckv"].ndim - 2  # (..., B, S, L): seq axis
            return {
                "ckv": _pad_seq(node["ckv"], ax, extra),
                "krope": _pad_seq(node["krope"], ax, extra),
            }
        if isinstance(node, dict):
            # cross-attn caches hold static encoder K/V: never grown
            return {k: (v if k == "cross" else walk(v)) for k, v in node.items()}
        return node  # SSM state / conv stream: fixed size

    return walk(caches)


def _ring_modulus(node: Any, acc: list) -> None:
    if _is_gqa(node) and "pos" in node:
        acc.append(node["pos"].shape[-1])
    elif isinstance(node, dict):
        for v in node.values():
            _ring_modulus(v, acc)


def ring_modulus(caches: dict) -> Optional[int]:
    """Modulus of the sliding-window ring leaves, or None if there are none."""
    acc: list = []
    _ring_modulus(caches, acc)
    return acc[0] if acc else None


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------


class SlotKVCache:
    """A pool of ``max_slots`` per-sequence caches sharing one buffer tree.

    Every leaf of ``buffers`` has shape ``(max_slots, *leaf_b1)`` where
    ``leaf_b1`` is the model's batch-1 cache shape at length ``max_len``
    (from ``model.cache_shapes(1, max_len)``). Allocation is a free-list;
    ``write`` pads a freshly prefilled batch-1 cache out to ``max_len`` and
    overwrites one slot in a single donated jit (no host round-trip).

    Thread safety: alloc/free/evict are lock-protected; ``write`` and the
    engine's decode tick mutate ``buffers`` and must be serialized by the
    caller (the engine's tick chain does this).
    """

    def __init__(self, model, max_slots: int, max_len: int) -> None:
        if max_slots < 1 or max_len < 1:
            raise ValueError("max_slots and max_len must be >= 1")
        self.max_slots = max_slots
        self.max_len = max_len
        self._slot_shapes = model.cache_shapes(1, max_len)
        self.buffers = jax.tree.map(
            lambda s: jnp.zeros((max_slots, *s.shape), s.dtype), self._slot_shapes
        )
        rings: list = []
        _ring_modulus(self._slot_shapes, rings)
        self._ring_w = rings[0] if rings else None
        self._lock = threading.Lock()
        self._free = list(range(max_slots - 1, -1, -1))  # pop() -> lowest slot
        self._live: set[int] = set()
        self.allocs = 0
        self.frees = 0
        self.evictions = 0
        self.peak_live = 0
        # tokens each live slot is provisioned to hold (written prefill +
        # decode growth intent) — powers the fragmentation stat: a flat
        # slot always reserves max_len, whatever the sequence needs
        self._target_len = [0] * max_slots

        def _write(buffers, new_cache, slot, prefill_len):
            padded = pad_caches_to(
                new_cache, self.max_len - prefill_len, ring_w=self._ring_w
            )
            return jax.tree.map(lambda b, n: b.at[slot].set(n), buffers, padded)

        # one jit; retraces per distinct prefill length (bucketed upstream)
        self._write_jit = jax.jit(_write, donate_argnums=(0,), static_argnums=(3,))

    # -- slot lifecycle -------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return len(self._live)

    def pages_for(self, length: int) -> int:
        """Pages a sequence of ``length`` tokens needs. A flat slot is one
        indivisible max_len-sized page, so the answer is always 1."""
        return 1

    def capacity_tokens(self, slot: int) -> int:
        """Token positions currently backed by storage for ``slot``."""
        return self.max_len

    def alloc(self, npages: int = 1) -> Optional[int]:
        """Claim a slot, or None when the pool is exhausted.

        ``npages`` is accepted for interface parity with
        :class:`PagedKVCache`; a flat slot always provisions max_len.
        """
        with self._lock:
            if not self._free:
                return None
            slot = self._free.pop()
            self._live.add(slot)
            self.allocs += 1
            self.peak_live = max(self.peak_live, len(self._live))
            return slot

    def grow_to(self, slot: int, length: int) -> bool:
        """Extend ``slot``'s provisioned length. Flat slots pre-provision
        max_len, so growth within capacity always succeeds."""
        if length > self.max_len:
            return False
        with self._lock:
            self._target_len[slot] = max(self._target_len[slot], length)
        return True

    def free(self, slot: int) -> None:
        """Return a slot to the pool (retired sequence)."""
        with self._lock:
            if slot not in self._live:
                raise ValueError(f"slot {slot} is not live")
            self._live.remove(slot)
            self._free.append(slot)
            self._target_len[slot] = 0
            self.frees += 1

    def evict(self, slot: int) -> None:
        """Forcibly free a live slot (capacity eviction); counted separately."""
        self.free(slot)
        with self._lock:
            self.evictions += 1

    # -- data movement --------------------------------------------------------

    def write(self, slot: int, cache: dict, prefill_len: int) -> None:
        """Install a batch-1 prefill cache (length ``prefill_len``) into ``slot``.

        Caller must hold the engine's tick serialization (buffers are
        donated). The cache is padded/re-laid out to ``max_len`` on device.
        """
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not live")
        if prefill_len > self.max_len:
            raise ValueError(f"prefill length {prefill_len} exceeds max_len {self.max_len}")
        with self._lock:
            self._target_len[slot] = max(self._target_len[slot], prefill_len)
        self.buffers = self._write_jit(
            self.buffers, cache, jnp.asarray(slot, jnp.int32), prefill_len
        )

    def read_slot(self, slot: int) -> dict:
        """The batch-1 cache tree currently stored in ``slot`` (for tests)."""
        return jax.tree.map(lambda b: b[slot], self.buffers)

    def stats(self) -> dict:
        """Lifecycle counters plus the §13 occupancy/fragmentation pair.

        For the flat layout one slot == one max_len-sized page:
        ``page_occupancy`` is slot occupancy and ``fragmentation`` is the
        fraction of provisioned token capacity the live sequences don't
        actually need — the over-allocation the paged cache exists to
        eliminate.
        """
        with self._lock:
            live = len(self._live)
            used = sum(self._target_len[s] for s in self._live)
            cap = live * self.max_len
            return {
                "max_slots": self.max_slots,
                "live": live,
                "free": len(self._free),
                "allocs": self.allocs,
                "frees": self.frees,
                "evictions": self.evictions,
                "peak_live": self.peak_live,
                "page_size": self.max_len,
                "pages_total": self.max_slots,
                "pages_live": live,
                "pages_free": len(self._free),
                "page_occupancy": live / self.max_slots,
                "fragmentation": (1.0 - used / cap) if cap else 0.0,
            }


# ---------------------------------------------------------------------------
# paged pool (DESIGN.md §13)
# ---------------------------------------------------------------------------


class _LeafSpec:
    """Per-leaf storage classification for the paged layout.

    ``kind`` is ``"page"`` for seq-growable leaves (GQA append K/V, MLA
    latents) and ``"slot"`` for fixed-size leaves (SSM state, conv streams,
    ring K/V/pos, static cross-attention K/V). ``ax`` is the sequence axis
    inside the batch-1 slot layout for page leaves.
    """

    __slots__ = ("kind", "ax")

    def __init__(self, kind: str, ax: int = -1) -> None:
        self.kind = kind
        self.ax = ax

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_LeafSpec({self.kind!r}, ax={self.ax})"


def _leaf_specs(shapes: dict) -> Any:
    """Mirror of the :func:`pad_caches_to` walk emitting a `_LeafSpec` tree
    with the exact structure of ``shapes`` (one spec per array leaf)."""

    def walk(node, static=False):
        if isinstance(node, dict):
            if not static and _is_gqa(node) and "pos" not in node:
                ax = node["k"].ndim - 3  # (..., B, S, KV, Dh)
                return {k: _LeafSpec("page", ax) for k in node}
            if not static and _is_mla(node):
                ax = node["ckv"].ndim - 2  # (..., B, S, L)
                return {k: _LeafSpec("page", ax) for k in node}
            return {k: walk(v, static or k == "cross") for k, v in node.items()}
        return _LeafSpec("slot")

    return walk(shapes)


class PagedKVCache:
    """Block-pooled KV cache: fixed-size pages, per-slot page tables.

    Storage layout (DESIGN.md §13):

    * every *growable* cache leaf lives in a page pool of shape
      ``(RESERVED + num_pages, ..., page_size, ...)`` where the sequence
      axis of the batch-1 slot layout is replaced by ``page_size`` and the
      physical page id leads;
    * *fixed-size* leaves (SSM recurrent state, conv streams, sliding-window
      rings, static encoder K/V) keep the flat ``(max_slots, ...)`` layout —
      they never grow, so paging them buys nothing;
    * two physical pages are reserved: page 0 is the **zero page** (never
      written; every unmapped page-table entry points at it, so a gathered
      logical cache is zero-padded exactly like the flat layout — the
      bit-identity invariant), page 1 is the **scratch page** (decode
      writes from inactive batch lanes land there and are never read).

    Allocation is a free-list of physical page ids; the per-slot page table
    is a host-side ``(max_slots, pages_per_seq)`` int32 array shipped to the
    device each tick (a few hundred bytes). ``write`` installs only the
    pages a prefill actually covers; ``grow_to`` appends page ids to a table
    row; ``free`` returns the row's pages. All O(pages touched).

    ``gather``/``scatter`` are pure functions traced inside the engine's
    decode-tick jit: gather reassembles each slot's logical ``max_len``
    cache from its pages (unmapped tail → zero page), scatter writes back
    the single page containing each lane's write index (inactive lanes →
    scratch page).

    Thread safety matches :class:`SlotKVCache`: page/slot accounting is
    lock-protected; ``write`` and the decode tick mutate ``pools`` and must
    be serialized by the caller (the engine's tick chain does this).
    """

    ZERO_PAGE = 0
    SCRATCH_PAGE = 1
    RESERVED = 2

    def __init__(
        self,
        model,
        max_slots: int,
        max_len: int,
        *,
        page_size: int = 64,
        num_pages: Optional[int] = None,
    ) -> None:
        if max_slots < 1 or max_len < 1 or page_size < 1:
            raise ValueError("max_slots, max_len and page_size must be >= 1")
        self.max_slots = max_slots
        self.max_len = max_len
        self.page_size = min(page_size, max_len)
        self.pages_per_seq = math.ceil(max_len / self.page_size)
        if num_pages is None:
            num_pages = max_slots * self.pages_per_seq
        if num_pages < self.pages_per_seq:
            raise ValueError(
                f"num_pages={num_pages} cannot hold one full sequence "
                f"({self.pages_per_seq} pages of {self.page_size} tokens)"
            )
        self.num_pages = num_pages

        self._slot_shapes = model.cache_shapes(1, max_len)
        self._spec_tree = _leaf_specs(self._slot_shapes)
        rings: list = []
        _ring_modulus(self._slot_shapes, rings)
        self._ring_w = rings[0] if rings else None

        ps, nphys = self.page_size, self.RESERVED + num_pages

        def make_pool(spec: _LeafSpec, s) -> jax.Array:
            if spec.kind == "slot":
                return jnp.zeros((max_slots, *s.shape), s.dtype)
            shp = s.shape
            return jnp.zeros(
                (nphys, *shp[: spec.ax], ps, *shp[spec.ax + 1 :]), s.dtype
            )

        self.pools = jax.tree.map(make_pool, self._spec_tree, self._slot_shapes)

        self._lock = threading.Lock()
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._live: set[int] = set()
        self._free_pages = list(range(nphys - 1, self.RESERVED - 1, -1))
        self._table = np.zeros((max_slots, self.pages_per_seq), np.int32)
        self._npages = [0] * max_slots
        self._target_len = [0] * max_slots
        self.allocs = 0
        self.frees = 0
        self.evictions = 0
        self.peak_live = 0
        self.page_allocs = 0
        self.page_frees = 0
        self.peak_pages_live = 0

        def _write(pools, new_cache, page_ids, slot, pad_len):
            npg = math.ceil(pad_len / ps)
            grown = pad_caches_to(new_cache, npg * ps - pad_len, ring_w=self._ring_w)

            def up(spec: _LeafSpec, pool, leaf):
                if spec.kind == "slot":
                    return pool.at[slot].set(leaf)
                shp = leaf.shape
                r = leaf.reshape(*shp[: spec.ax], npg, ps, *shp[spec.ax + 1 :])
                return pool.at[page_ids].set(jnp.moveaxis(r, spec.ax, 0))

            return jax.tree.map(up, self._spec_tree, pools, grown)

        # one jit; retraces per distinct prefill length (bucketed upstream)
        self._write_jit = jax.jit(_write, donate_argnums=(0,), static_argnums=(4,))

    # -- page/slot accounting -------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free_slots)

    @property
    def num_live(self) -> int:
        return len(self._live)

    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def pages_live(self) -> int:
        return self.num_pages - len(self._free_pages)

    def pages_for(self, length: int) -> int:
        """Pages a sequence of ``length`` tokens needs."""
        return max(1, math.ceil(length / self.page_size))

    def capacity_tokens(self, slot: int) -> int:
        """Token positions currently backed by physical pages for ``slot``."""
        return self._npages[slot] * self.page_size

    def alloc(self, npages: int = 1) -> Optional[int]:
        """Claim a slot backed by ``npages`` pages, or None when either the
        slot pool or the page pool cannot satisfy the request."""
        if npages > self.pages_per_seq:
            return None
        with self._lock:
            if not self._free_slots or len(self._free_pages) < npages:
                return None
            slot = self._free_slots.pop()
            self._live.add(slot)
            for i in range(npages):
                self._table[slot, i] = self._free_pages.pop()
            self._npages[slot] = npages
            self.allocs += 1
            self.page_allocs += npages
            self.peak_live = max(self.peak_live, len(self._live))
            self.peak_pages_live = max(self.peak_pages_live, self.pages_live)
            return slot

    def grow_to(self, slot: int, length: int) -> bool:
        """Back ``slot`` with pages covering ``length`` tokens.

        All-or-nothing: returns False (allocating nothing) when the free
        list cannot cover the missing pages — the engine's page-pressure
        preemption path. O(pages appended).
        """
        if length > self.max_len:
            return False
        need = self.pages_for(length)
        with self._lock:
            if slot not in self._live:
                raise ValueError(f"slot {slot} is not live")
            have = self._npages[slot]
            extra = need - have
            if extra <= 0:
                self._target_len[slot] = max(self._target_len[slot], length)
                return True
            if len(self._free_pages) < extra:
                return False
            for i in range(have, need):
                self._table[slot, i] = self._free_pages.pop()
            self._npages[slot] = need
            self._target_len[slot] = max(self._target_len[slot], length)
            self.page_allocs += extra
            self.peak_pages_live = max(self.peak_pages_live, self.pages_live)
            return True

    def free(self, slot: int) -> None:
        """Return a slot and all its pages to the pools (O(pages held))."""
        with self._lock:
            if slot not in self._live:
                raise ValueError(f"slot {slot} is not live")
            self._live.remove(slot)
            self._free_slots.append(slot)
            npg = self._npages[slot]
            for i in range(npg):
                self._free_pages.append(int(self._table[slot, i]))
            self._table[slot, :] = self.ZERO_PAGE
            self._npages[slot] = 0
            self._target_len[slot] = 0
            self.page_frees += npg
            self.frees += 1

    def evict(self, slot: int) -> None:
        """Forcibly free a live slot (capacity eviction); counted separately."""
        self.free(slot)
        with self._lock:
            self.evictions += 1

    # -- data movement --------------------------------------------------------

    def write(self, slot: int, cache: dict, prefill_len: int) -> None:
        """Install a batch-1 prefill cache (length ``prefill_len``) into
        ``slot``'s pages. Only ``ceil(prefill_len / page_size)`` pages are
        touched; the caller must hold the engine's tick serialization
        (pools are donated)."""
        if prefill_len > self.max_len:
            raise ValueError(f"prefill length {prefill_len} exceeds max_len {self.max_len}")
        npg = self.pages_for(prefill_len)
        with self._lock:
            if slot not in self._live:
                raise ValueError(f"slot {slot} is not live")
            if self._npages[slot] < npg:
                raise ValueError(
                    f"slot {slot} holds {self._npages[slot]} pages, prefill needs {npg}"
                )
            page_ids = jnp.asarray(self._table[slot, :npg])
            self._target_len[slot] = max(self._target_len[slot], prefill_len)
        self.pools = self._write_jit(
            self.pools, cache, page_ids, jnp.asarray(slot, jnp.int32), prefill_len
        )

    def gather(self, pools, tables: jax.Array):
        """Reassemble the ``(max_slots, ...)`` logical cache tree from pages.

        Pure/traceable; ``tables`` is the device copy of the page table.
        Unmapped entries point at the zero page, so the result is
        bit-identical to the flat slot layout.
        """
        ps = self.page_size

        def g(spec: _LeafSpec, pool):
            if spec.kind == "slot":
                return pool
            pages = pool[tables]  # (slots, P, *pre, page, *post)
            pages = jnp.moveaxis(pages, 1, 1 + spec.ax)  # (slots, *pre, P, page, *post)
            shp = pages.shape
            return pages.reshape(
                *shp[: 1 + spec.ax], shp[1 + spec.ax] * ps, *shp[3 + spec.ax :]
            )

        return jax.tree.map(g, self._spec_tree, pools)

    def scatter(self, pools, updated, dest_ids: jax.Array, idx: jax.Array):
        """Write each lane's touched page back into the pools.

        Pure/traceable. ``updated`` is the decode-step output cache tree in
        the logical ``(max_slots, ...)`` layout; a decode step only writes
        position ``idx[slot]``, so the single page containing it is
        extracted per lane and scattered to physical page ``dest_ids[slot]``
        (the scratch page for inactive lanes). Fixed-size leaves are
        replaced wholesale, exactly like the flat layout.
        """
        ps = self.page_size
        start = (idx // ps) * ps

        def s(spec: _LeafSpec, pool, upd):
            if spec.kind == "slot":
                return upd

            def one(u, st):
                return jax.lax.dynamic_slice_in_dim(u, st, ps, axis=spec.ax)

            return pool.at[dest_ids].set(jax.vmap(one)(upd, start))

        return jax.tree.map(s, self._spec_tree, pools, updated)

    def tick_inputs(self, feed: dict) -> tuple:
        """Host-side per-tick arrays: ``(page_table, dest_ids)``.

        ``feed`` maps live slot -> write index for this tick. ``dest_ids``
        routes each lane's written page: the physical page containing the
        write index for live lanes, the scratch page for idle lanes.
        """
        with self._lock:
            tables = self._table.copy()
        dest = np.full((self.max_slots,), self.SCRATCH_PAGE, np.int32)
        for slot, fi in feed.items():
            dest[slot] = tables[slot, fi // self.page_size]
        return tables, dest

    def read_slot(self, slot: int) -> dict:
        """The batch-1 logical cache currently mapped by ``slot`` (tests)."""
        gathered = self.gather(self.pools, jnp.asarray(self._table))
        return jax.tree.map(lambda b: b[slot], gathered)

    def stats(self) -> dict:
        """Lifecycle counters plus §13 page-occupancy and fragmentation.

        ``page_occupancy``: fraction of the usable page pool currently
        mapped by live sequences. ``fragmentation``: fraction of the token
        capacity inside those live pages that no sequence needs (internal
        fragmentation — bounded by ``page_size - 1`` tokens per sequence,
        versus up to ``max_len - prompt`` per sequence for the flat layout).
        """
        with self._lock:
            live_pages = self.num_pages - len(self._free_pages)
            used = sum(self._target_len[s] for s in self._live)
            cap = live_pages * self.page_size
            return {
                "max_slots": self.max_slots,
                "live": len(self._live),
                "free": len(self._free_slots),
                "allocs": self.allocs,
                "frees": self.frees,
                "evictions": self.evictions,
                "peak_live": self.peak_live,
                "page_size": self.page_size,
                "pages_total": self.num_pages,
                "pages_live": live_pages,
                "pages_free": len(self._free_pages),
                "page_allocs": self.page_allocs,
                "page_frees": self.page_frees,
                "peak_pages_live": self.peak_pages_live,
                "page_occupancy": live_pages / self.num_pages,
                "fragmentation": (1.0 - used / cap) if cap else 0.0,
            }
