"""qwen1.5-4b [dense]: 40L d=2560 20H (GQA kv=20) d_ff=6912 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151_936,
    kv_pad_to=32,  # beyond-paper: zero-padded KV heads (exact; see EXPERIMENTS §Perf)
    head_dim=128,
    qkv_bias=True,
    rope_theta=10_000.0,
    loss_chunk=512,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen1.5-4b-reduced",
        num_layers=3, d_model=96, num_heads=4, num_kv_heads=4, head_dim=24,
        d_ff=192, vocab_size=1024, loss_chunk=0,
    )
