"""phi4-mini-3.8b [dense]: 32L d=3072 24H (GQA kv=8) d_ff=8192
vocab=200064. RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200_064,
    kv_pad_to=16,  # beyond-paper: zero-padded KV heads (exact; see EXPERIMENTS §Perf)
    head_dim=128,
    rope_theta=10_000.0,
    tie_embeddings=True,
    loss_chunk=512,  # 200k vocab: chunk the CE to bound logits memory
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="phi4-mini-3.8b-reduced",
        num_layers=3, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=1024, loss_chunk=0,
    )
