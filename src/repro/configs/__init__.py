"""Architecture registry: the 10 assigned configs + reduced smoke variants."""
from importlib import import_module

from .base import SHAPES, ModelConfig, param_count

_MODULES = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "qwen1.5-4b": "qwen1p5_4b",
    "hymba-1.5b": "hymba_1p5b",
    "whisper-medium": "whisper_medium",
    "paligemma-3b": "paligemma_3b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-1.3b": "mamba2_1p3b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[name]}").CONFIG


def get_reduced(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[name]}").reduced()


__all__ = ["ARCH_NAMES", "SHAPES", "ModelConfig", "get_config", "get_reduced", "param_count"]
