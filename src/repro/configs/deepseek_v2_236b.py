"""deepseek-v2-236b [moe]: 60L d=5120 128H, MLA (kv_lora=512, rope 64),
2 shared + 160 routed experts top-6 (d_ff 1536), first layer dense
(d_ff 12288), vocab=102400. [arXiv:2405.04434; hf]

Sharding override: per-expert hidden dim additionally sharded over `data`
(2D expert sharding) so the 236B fit on 256 chips (DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12_288,  # the first (dense) layer
    vocab_size=102_400,
    head_dim=128,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    capacity_factor=1.25,
    loss_chunk=512,
    sharding_rules=(("expert_mlp", "data"),),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-236b-reduced",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, q_lora_rank=32, kv_lora_rank=24,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        num_experts=8, experts_per_token=2, num_shared_experts=2,
        moe_d_ff=96, loss_chunk=0, sharding_rules=(),
    )
