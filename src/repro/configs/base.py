"""Model/architecture configuration schema.

One dataclass covers every assigned family (dense / moe / ssm / hybrid /
enc-dec audio / vlm). Family-specific fields default to "off". Each assigned
architecture lives in ``repro/configs/<id>.py`` as a module-level ``CONFIG``
plus a ``reduced()`` smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Shape suites assigned to the LM families (seq_len, global_batch).
SHAPES = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // num_heads
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp (plain)
    qkv_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale
    norm: str = "rms"  # rms | ln (whisper)
    norm_eps: float = 1e-5
    use_rope: bool = True  # False = absolute/sinusoidal positions (whisper)
    rope_theta: float = 10_000.0
    max_seq_len: int = 4_096  # advisory; shapes override

    # -- attention variants -------------------------------------------------
    attention: str = "gqa"  # gqa | mla | none
    # Zero-padded KV heads (beyond-paper TP optimization, EXPERIMENTS.md
    # §Perf): pad the KV-head axis to this count, preserving the GQA group
    # size, with exactly-zero pad weights. Zero pads are provably inert
    # (zero V ⇒ zero outputs ⇒ zero grads ⇒ stay zero under AdamW), so the
    # model function is IDENTICAL while every head dim becomes divisible by
    # the 16-way model axis (no row-parallel all-reduce fallback).
    kv_pad_to: int = 0
    window: Optional[int] = None  # sliding-window size (None = full)
    global_layers: Tuple[int, ...] = ()  # layer indices with full attention
    # MLA (deepseek-v2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # -- MoE ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (d_ff of routed experts)
    first_dense_layers: int = 0  # deepseek-v2: first k layers use dense MLP
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    # -- SSM (mamba2 SSD) -----------------------------------------------------
    ssm_state: int = 0  # N (state dim per head); 0 = no ssm
    ssm_heads: int = 0  # defaults to num_heads when hybrid, d_inner/64 for ssm
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4

    # -- enc-dec (whisper) ------------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1_500  # whisper: 30s of audio at 50 fps after conv

    # -- vlm (paligemma) --------------------------------------------------------
    vision_dim: int = 0  # stub frontend embedding dim (SigLIP width)
    num_image_tokens: int = 0

    # -- sharding ---------------------------------------------------------------
    sharding_rules: Tuple[Tuple[str, str], ...] = ()  # logical->mesh overrides

    # -- numerics / execution ---------------------------------------------------
    dtype: str = "bfloat16"
    remat: str = "full"  # full | none
    loss_chunk: int = 0  # 0 = unchunked cross-entropy
    # use the Pallas kernels on TPU (dry-run/CPU uses the jnp reference path)
    use_kernels: bool = False

    # ------------------------------------------------------------------------

    def __post_init__(self) -> None:
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def kv_heads_padded(self) -> int:
        return max(self.kv_pad_to, self.num_kv_heads) if self.num_kv_heads else 0

    @property
    def heads_padded(self) -> int:
        if not self.num_heads:
            return 0
        group = self.num_heads // max(self.num_kv_heads, 1)
        return self.kv_heads_padded * group

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no full-attention-over-full-seq layers,
        except a bounded number of global layers (hymba-style)."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid" and self.window is not None:
            return True
        return False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def shapes(self) -> dict:
        """The shape suite this arch runs (per assignment skip rules)."""
        out = {}
        for name, spec in SHAPES.items():
            if name == "long_500k" and not self.sub_quadratic:
                continue  # full-attention archs skip (DESIGN.md §4)
            out[name] = spec
        return out


def param_count(cfg: ModelConfig) -> dict:
    """Analytic parameter counts (total + active) for MODEL_FLOPS."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    embed = V * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    if cfg.attention == "gqa":
        per_layer += d * h * hd + 2 * d * kv * hd + h * hd * d
    elif cfg.attention == "mla":
        qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        q_in = cfg.q_lora_rank or d
        per_layer += (d * cfg.q_lora_rank if cfg.q_lora_rank else 0)
        per_layer += q_in * h * qk_hd
        per_layer += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        per_layer += cfg.kv_lora_rank * h * (cfg.qk_nope_head_dim + cfg.v_head_dim)
        per_layer += h * cfg.v_head_dim * d
    mlp_mult = 3 if cfg.act in ("silu", "gelu") else 2
    dense_mlp = mlp_mult * d * cfg.d_ff
    if cfg.is_moe:
        routed = cfg.num_experts * mlp_mult * d * cfg.moe_d_ff
        shared = cfg.num_shared_experts * mlp_mult * d * cfg.moe_d_ff
        active_mlp = (cfg.experts_per_token + cfg.num_shared_experts) * mlp_mult * d * cfg.moe_d_ff
        router = d * cfg.num_experts
        moe_layers = L - cfg.first_dense_layers
        total_mlp = moe_layers * (routed + shared + router) + cfg.first_dense_layers * dense_mlp
        active_mlp_total = moe_layers * (active_mlp + router) + cfg.first_dense_layers * dense_mlp
    else:
        total_mlp = L * dense_mlp
        active_mlp_total = total_mlp
    if cfg.ssm_state:
        d_inner = cfg.ssm_expand * d if cfg.family == "ssm" else cfg.num_heads * cfg.head_dim
        nh = cfg.ssm_heads or d_inner // cfg.ssm_head_dim
        # in/out/gate projections dominate; per-head state params are small
        ssm_per_layer = (
            d * d_inner * 2 + d_inner * d + d_inner * cfg.conv_kernel + nh * (2 + cfg.ssm_state)
        )
        per_layer += ssm_per_layer
    attn_total = L * per_layer
    enc = 0
    if cfg.is_encdec:
        enc_attn = d * h * hd * 2 + 2 * d * kv * hd * 2 + 2 * h * hd * d  # self+cross
        enc = cfg.encoder_layers * (enc_attn + dense_mlp)
    total = embed + attn_total + total_mlp + enc
    active = embed + attn_total + active_mlp_total + enc
    return dict(total=total, active=active, non_embedding=total - embed)
