"""whisper-medium [audio enc-dec]: 24L(enc)+24L(dec) d=1024 16H d_ff=4096
vocab=51865. Conv frontend is a STUB: input_specs supplies precomputed frame
embeddings (B, 1500, d_model). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    head_dim=64,
    norm="ln",
    act="gelu_mlp",
    use_rope=False,
    encoder_layers=24,
    encoder_seq=1500,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-medium-reduced",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, encoder_layers=2, encoder_seq=12,
    )
