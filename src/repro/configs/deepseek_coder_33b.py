"""deepseek-coder-33b [dense]: llama-arch, 62L d=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256. [arXiv:2401.14196; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    kv_pad_to=16,  # beyond-paper: zero-padded KV heads (exact; see EXPERIMENTS §Perf)
    head_dim=128,
    rope_theta=100_000.0,
    max_seq_len=16_384,
    loss_chunk=512,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-coder-33b-reduced",
        num_layers=3, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512, loss_chunk=0,
    )
