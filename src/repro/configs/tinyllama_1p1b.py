"""tinyllama-1.1b [dense]: llama2-arch small, 22L d=2048 32H (GQA kv=4)
d_ff=5632 vocab=32000. [arXiv:2401.02385; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32_000,
    head_dim=64,
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="tinyllama-1.1b-reduced",
        num_layers=3, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512,
    )
