"""mamba2-1.3b [ssm]: 48L d=2048, attention-free SSD (state-space duality),
ssm_state=128, vocab=50280. [arXiv:2405.21060; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    head_dim=0,
    attention="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,  # d_inner=4096 -> 64 heads
    ssm_chunk=256,
    conv_kernel=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-1.3b-reduced",
        num_layers=3, d_model=64, vocab_size=512, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8,
    )
