"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (GQA kv=8) vocab=49155,
32 experts top-8, expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=0,  # every layer is MoE
    vocab_size=49_155,
    kv_pad_to=16,  # beyond-paper: zero-padded KV heads (exact; see EXPERIMENTS §Perf)
    head_dim=64,
    tie_embeddings=True,
    num_experts=32,
    experts_per_token=8,
    moe_d_ff=512,
    capacity_factor=1.25,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-1b-a400m-reduced",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        vocab_size=512, num_experts=8, experts_per_token=2, moe_d_ff=96,
    )
