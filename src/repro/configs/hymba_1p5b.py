"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attention + Mamba(SSD) heads, SWA except 3 global-attention layers
(first/middle/last), ssm_state=16. [arXiv:2411.13676; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    head_dim=64,
    window=1024,
    global_layers=(0, 15, 31),
    ssm_state=16,
    ssm_heads=25,  # parallel SSD heads match attention heads
    ssm_head_dim=64,
    ssm_chunk=64,
    conv_kernel=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="hymba-1.5b-reduced",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, window=8, global_layers=(0, 2, 4),
        ssm_state=8, ssm_heads=4, ssm_head_dim=16, ssm_chunk=8,
    )
