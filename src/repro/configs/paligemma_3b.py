"""paligemma-3b [vlm]: SigLIP frontend STUB + gemma backbone, 18L d=2048
8H (MQA kv=1) d_ff=16384 vocab=257216. input_specs supplies precomputed
patch embeddings (B, 256, 1152). [arXiv:2407.07726; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257_216,
    head_dim=256,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
    vision_dim=1152,
    num_image_tokens=256,
    loss_chunk=256,  # 257k vocab
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="paligemma-3b-reduced",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=192, vocab_size=1024, vision_dim=48, num_image_tokens=4,
        loss_chunk=0,
    )
