"""Wavefront example: a numeric task-graph workload on the pool.

Blocked Gauss-Seidel-style sweep over an N x N grid of tiles: tile (i, j)
depends on (i-1, j) and (i, j-1) — the canonical anti-diagonal wavefront
task graph (also a Taskflow benchmark). Tiles do real numpy work that
releases the GIL, so the pool's workers genuinely overlap.

    PYTHONPATH=src python examples/wavefront.py [--tiles 12] [--size 128]
"""
import argparse
import time

import numpy as np

from repro.core import SerialExecutor, TaskGraph, ThreadPool


def build(grid: int, size: int, rng: np.random.Generator):
    field = [[rng.standard_normal((size, size)) for _ in range(grid)] for _ in range(grid)]

    def relax(i: int, j: int) -> None:
        tile = field[i][j]
        if i > 0:
            tile = tile + 0.25 * field[i - 1][j]
        if j > 0:
            tile = tile + 0.25 * field[i][j - 1]
        # a bit of real GIL-releasing work
        field[i][j] = np.tanh(tile @ tile.T) @ tile

    g = TaskGraph("wavefront")
    tasks = {}
    for i in range(grid):
        for j in range(grid):
            t = g.add(lambda i=i, j=j: relax(i, j), name=f"t{i}.{j}")
            if i > 0:
                t.succeed(tasks[(i - 1, j)])
            if j > 0:
                t.succeed(tasks[(i, j - 1)])
            tasks[(i, j)] = t
    return g, field


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiles", type=int, default=12)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--threads", type=int, default=4)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    g, field = build(args.tiles, args.size, rng)
    g.validate()
    print(f"graph: {len(g)} tasks, critical path {g.critical_path():.0f}")

    t0 = time.perf_counter()
    SerialExecutor().run(g)
    t_serial = time.perf_counter() - t0

    g2, _ = build(args.tiles, args.size, rng)
    t0 = time.perf_counter()
    with ThreadPool(args.threads) as pool:
        pool.run(g2)
    t_pool = time.perf_counter() - t0

    print(f"serial: {t_serial * 1e3:8.1f} ms")
    print(f"pool({args.threads}): {t_pool * 1e3:6.1f} ms  "
          f"(speedup {t_serial / t_pool:.2f}x; 1-core containers bound this at ~1)")


if __name__ == "__main__":
    main()
