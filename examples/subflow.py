"""Dynamic subflows (DESIGN.md §10): fan-out sized by data seen at runtime.

A map-style aggregation over a "dataset" whose partitioning is unknown when
the graph is built: a single ``takes_runtime`` task inspects the data
*inside a worker* and spawns one reduce task per discovered partition plus
a gather — the subflow. The executor joins the subflow before releasing
the spawner's successor, so ``report`` always sees every partial sum
(join-before-successor). ``to_dot()`` renders the spawned tasks as a
cluster after the run.

    PYTHONPATH=src python examples/subflow.py
"""
import numpy as np

from repro.core import Executor, Runtime, TaskGraph


def make_dataset(seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    nparts = int(rng.integers(3, 9))  # unknown at graph-build time
    return {
        f"part{i}": rng.standard_normal(int(rng.integers(10_000, 50_000))) for i in range(nparts)
    }


def main() -> None:
    g = TaskGraph("partition-sum")
    load = g.add(make_dataset, name="load")

    def spawn_reducers(rt: Runtime, dataset: dict) -> object:
        # one task per partition — sized by the data this worker just saw
        parts = [
            rt.add(lambda a=arr: float(np.square(a).sum()), name=f"reduce:{key}")
            for key, arr in dataset.items()
        ]
        return rt.gather(parts, name="partials")

    spawner = g.add(spawn_reducers, name="spawn", takes_inputs=True, takes_runtime=True)
    spawner.succeed(load)

    def report(partials: list) -> float:
        # the spawner's value is the gather's result — the join unwrapped it
        print(f"subflow spawned {len(partials)} reducers; sum of squares = {sum(partials):.2f}")
        return sum(partials)

    total = g.then(spawner, report, name="report")

    with Executor(4) as ex:
        ex.run(g).result(60)
        dataset = load.result
        expect = sum(float(np.square(a).sum()) for a in dataset.values())
        assert abs(total.result - expect) < 1e-6 * max(1.0, expect)
        dot = g.to_dot()
        assert 'subgraph "cluster_' in dot
        print(f"to_dot renders the subflow as a cluster ({len(spawner._spawned)} spawned tasks)")


if __name__ == "__main__":
    main()
