"""Serving example: a thin client of the continuous-batching engine.

Submits a handful of prompts with different lengths and token budgets to
``repro.serve.ServeEngine`` — prefill runs as low-priority tasks on the
work-stealing pool, decode ticks at high priority, and sequences join/retire
between ticks (iteration-level batching).

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b] [--new 16]

Uses the arch's REDUCED config so it runs in seconds on CPU; pass
--full to build the real config (needs memory/patience).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.models import build_model
from repro.serve import ServeEngine

# the engine serves text-prompt families; encdec/vlm need non-token inputs
SERVABLE = tuple(
    n for n in ARCH_NAMES
    if not get_config(n).is_encdec and get_config(n).family != "vlm"
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=SERVABLE)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family}")
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=int(rng.integers(args.prompt_len // 2, args.prompt_len + 1)))
        for _ in range(args.requests)
    ]
    budgets = [int(rng.integers(max(2, args.new // 2), args.new + 1)) for _ in range(args.requests)]

    max_len = args.prompt_len + args.new + 1
    buckets = None
    if ServeEngine.supports_prefill_buckets(cfg):
        buckets = (args.prompt_len // 2, args.prompt_len)

    t0 = time.perf_counter()
    with ServeEngine(
        model, params, max_slots=args.slots, max_len=max_len, prefill_buckets=buckets
    ) as engine:
        handles = [engine.submit(p, n) for p, n in zip(prompts, budgets)]
        outs = [h.result(600) for h in handles]
        wall = time.perf_counter() - t0
        stats = engine.stats()

    total = sum(len(o) for o in outs)
    print(f"{len(outs)} requests, {total} tokens in {wall * 1e3:.1f} ms "
          f"(incl. compile) -> {total / max(wall, 1e-9):,.0f} tok/s")
    print(f"ticks={stats['ticks']} mean_occupancy={stats['mean_occupancy']:.2f} "
          f"pool_steals={stats['pool']['steals']}")
    print("generated token ids (first request):", list(map(int, outs[0])))


if __name__ == "__main__":
    main()
