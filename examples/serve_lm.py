"""Serving example: batched prefill + greedy decode with a KV cache.

Demonstrates the inference path of every family: dense GQA cache, MLA
compressed cache, SSM recurrent state, sliding-window ring buffers.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b] [--new 16]

Uses the arch's REDUCED config so it runs in seconds on CPU; pass
--full to build the real config (needs memory/patience).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.models import build_model
from repro.models.lm import extend_caches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family}")
    params = model.init(jax.random.PRNGKey(0))

    B, S = args.batch, args.prompt_len
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((B, cfg.num_image_tokens, cfg.vision_dim), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    caches = extend_caches(caches, args.new)

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    pos = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    t0 = time.perf_counter()
    for i in range(args.new - 1):
        logits, caches = decode(params, tok, caches, jnp.asarray(pos + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill: {B}x{S} tokens in {t_prefill * 1e3:.1f} ms "
          f"(incl. compile)")
    print(f"decode:  {args.new - 1} steps x {B} seqs in {t_decode * 1e3:.1f} ms "
          f"-> {B * (args.new - 1) / max(t_decode, 1e-9):,.0f} tok/s")
    print("generated token ids (first sequence):", gen[0].tolist())


if __name__ == "__main__":
    main()
