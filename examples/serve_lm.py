"""Serving example: a thin client of the continuous-batching engine.

Submits a handful of prompts with different lengths and token budgets to
``repro.serve.ServeEngine`` — prefill runs as low-priority tasks on the
work-stealing pool, decode ticks at high priority, and sequences join/retire
between ticks (iteration-level batching). KV storage is the §13 paged pool,
the admit queue is bounded (``QueueFull`` backpressure), every request
carries a TTFT deadline, and the first request is **streamed** token by
token while the rest resolve through their futures.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b] [--new 16]

Uses the arch's REDUCED config so it runs in seconds on CPU; pass
--full to build the real config (needs memory/patience).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.models import build_model
from repro.serve import QueueFull, ServeEngine

# the engine serves text-prompt families; encdec/vlm need non-token inputs
SERVABLE = tuple(
    n for n in ARCH_NAMES
    if not get_config(n).is_encdec and get_config(n).family != "vlm"
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=SERVABLE)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--deadline", type=float, default=60.0,
                    help="per-request TTFT deadline (seconds)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family}")
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(
            0, cfg.vocab_size, size=int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        )
        for _ in range(args.requests)
    ]
    budgets = [int(rng.integers(max(2, args.new // 2), args.new + 1)) for _ in range(args.requests)]

    max_len = args.prompt_len + args.new + 1
    buckets = None
    if ServeEngine.supports_prefill_buckets(cfg):
        buckets = (args.prompt_len // 2, args.prompt_len)

    t0 = time.perf_counter()
    with ServeEngine(
        model, params, max_slots=args.slots, max_len=max_len,
        prefill_buckets=buckets,
        max_waiting=4 * args.slots,  # bounded admit queue: QueueFull past this
    ) as engine:
        handles = []
        for p, n in zip(prompts, budgets):
            while True:
                try:
                    handles.append(engine.submit(p, n, deadline=args.deadline))
                    break
                except QueueFull:  # backpressure: shed upstream or retry
                    time.sleep(0.002)

        # stream the first request token-by-token as its decode ticks land;
        # `async for tok in handle` is the asyncio equivalent
        streamed = []
        for tok in handles[0]:
            streamed.append(int(tok))
        print(f"request 0 streamed {len(streamed)} tokens, "
              f"TTFT {handles[0].ttft * 1e3:.1f} ms")

        outs = [h.result(600) for h in handles]
        wall = time.perf_counter() - t0
        stats = engine.stats()

    assert streamed == list(map(int, outs[0]))  # stream and future agree
    total = sum(len(o) for o in outs)
    ttfts = sorted(h.ttft for h in handles)
    print(f"{len(outs)} requests, {total} tokens in {wall * 1e3:.1f} ms "
          f"(incl. compile) -> {total / max(wall, 1e-9):,.0f} tok/s")
    print(f"TTFT p50={ttfts[len(ttfts) // 2] * 1e3:.1f} ms "
          f"max={ttfts[-1] * 1e3:.1f} ms "
          f"deadline_misses={stats['deadline_misses']} rejected={stats['rejected']}")
    kv = stats["kv"]
    print(f"ticks={stats['ticks']} mean_occupancy={stats['mean_occupancy']:.2f} "
          f"preemptions={stats['preemptions']} "
          f"pages={kv['pages_live']}/{kv['pages_total']} live "
          f"(peak {kv.get('peak_pages_live', kv['peak_live'])}) "
          f"pool_steals={stats['pool']['steals']}")
    print("generated token ids (first request):", list(map(int, outs[0])))


if __name__ == "__main__":
    main()
