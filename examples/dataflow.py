"""Dataflow runtime tour (DESIGN.md §8): value-passing graphs, composition,
re-running, and Chrome-trace observation.

    PYTHONPATH=src python examples/dataflow.py [trace.json]

Pass a path to also write a chrome://tracing-loadable trace of the run.
"""
import sys

from repro.core import ChromeTraceObserver, StatsObserver, TaskGraph, ThreadPool


def diamond_demo(pool: ThreadPool) -> None:
    # results flow along edges as ordered arguments — no captured dicts
    g = TaskGraph("diamond")
    a = g.add(lambda: 2, name="a")
    b = g.then(a, lambda x: x + 1, name="b")  # b(a())
    c = g.then(a, lambda x: x * 10, name="c")  # c(a())
    d = g.gather([b, c], lambda bx, cx: bx + cx, name="d")  # d(b(), c())
    for round_idx in range(3):  # build once, run N times
        g.as_future(pool).result(10)
        print(f"run {round_idx}: (2+1) + (2*10) = {d.result}")
    assert g.run_count == 3


def compose_demo(pool: ThreadPool) -> None:
    # a subgraph embeds as a module behind source/sink boundary tasks;
    # the sink gathers the subgraph's results as a list
    shards = TaskGraph("shards")
    for i in range(4):
        shards.add(lambda i=i: i * i, name=f"shard{i}")
    outer = TaskGraph("outer")
    prep = outer.add(lambda: print("prepare"), name="prepare")
    m = outer.compose(shards)
    m.source.after(prep)
    total = outer.then(m.sink, sum, name="total")
    outer.as_future(pool).result(10)
    print(f"sum of squares via composed module: {total.result}")


def main() -> None:
    stats = StatsObserver()
    tracer = ChromeTraceObserver()
    with ThreadPool(4, observers=[stats, tracer]) as pool:
        diamond_demo(pool)
        compose_demo(pool)
        num_workers = pool.num_threads
    print("pool stats:", stats.summary())
    if len(sys.argv) > 1:
        tracer.save(sys.argv[1], num_workers=num_workers)
        print(f"wrote {sys.argv[1]} — open in chrome://tracing")


if __name__ == "__main__":
    main()
