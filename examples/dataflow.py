"""Dataflow runtime tour (DESIGN.md §8, §10): value-passing graphs,
composition, re-running, Chrome-trace observation and the asyncio bridge —
all through the :class:`Executor` facade (the post-§10 front door; the raw
``ThreadPool``/``as_future`` surface still works underneath).

    PYTHONPATH=src python examples/dataflow.py [trace.json]

Pass a path to also write a chrome://tracing-loadable trace of the run.
"""
import asyncio
import sys

from repro.core import ChromeTraceObserver, Executor, StatsObserver, TaskGraph


def diamond_demo(ex: Executor) -> None:
    # results flow along edges as ordered arguments — no captured dicts
    g = TaskGraph("diamond")
    a = g.add(lambda: 2, name="a")
    b = g.then(a, lambda x: x + 1, name="b")  # b(a())
    c = g.then(a, lambda x: x * 10, name="c")  # c(a())
    d = g.gather([b, c], lambda bx, cx: bx + cx, name="d")  # d(b(), c())
    for round_idx in range(3):  # build once, run N times
        ex.run(g).result(10)
        print(f"run {round_idx}: (2+1) + (2*10) = {d.result}")
    assert g.run_count == 3


def compose_demo(ex: Executor) -> None:
    # a subgraph embeds as a module behind source/sink boundary tasks;
    # the sink gathers the subgraph's results as a list
    shards = TaskGraph("shards")
    for i in range(4):
        shards.add(lambda i=i: i * i, name=f"shard{i}")
    outer = TaskGraph("outer")
    prep = outer.add(lambda: print("prepare"), name="prepare")
    m = outer.compose(shards)
    m.source.after(prep)
    total = outer.then(m.sink, sum, name="total")
    ex.run(outer).result(10)
    print(f"sum of squares via composed module: {total.result}")


def asyncio_demo(ex: Executor) -> None:
    # co_run awaits pool work from an event loop without blocking it
    async def serve_two():
        g1, g2 = TaskGraph(), TaskGraph()
        r1 = g1.add(lambda: sum(range(1000)))
        r2 = g2.add(lambda: max(range(1000)))
        await asyncio.gather(ex.co_run(g1), ex.co_run(g2))
        return r1.result, r2.result

    s, m = asyncio.run(serve_two())
    print(f"awaited two graphs from asyncio: sum={s} max={m}")


def main() -> None:
    stats = StatsObserver()
    tracer = ChromeTraceObserver()
    with Executor(4, observers=[stats, tracer]) as ex:
        diamond_demo(ex)
        compose_demo(ex)
        asyncio_demo(ex)
        num_workers = ex.num_threads
    print("pool stats:", stats.summary())
    if len(sys.argv) > 1:
        tracer.save(sys.argv[1], num_workers=num_workers)
        print(f"wrote {sys.argv[1]} — open in chrome://tracing")


if __name__ == "__main__":
    main()
