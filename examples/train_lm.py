"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full substrate on CPU: model zoo config, ThreadPool-prefetched
synthetic data, AdamW, async checkpointing with atomic commit + resume, and
(optionally) an injected failure mid-run to demonstrate restart/resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--fail]

(A ~100M model on one CPU core takes ~1s/step at seq 256; defaults keep the
run a few minutes. Use --tiny for a 60-second sanity run.)
"""
import argparse
import time

from repro.configs.base import ModelConfig
from repro.runtime import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    # ~103M params: 12L, d=768, llama-style
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32_000,
        remat="none", dtype="float32",
    )


def model_tiny() -> ModelConfig:
    return ModelConfig(
        name="lm-tiny", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=688, vocab_size=4_096,
        remat="none", dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--fail", action="store_true", help="inject a failure mid-run")
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    from repro.configs.base import param_count

    print(f"model: {cfg.name}  params≈{param_count(cfg)['total'] / 1e6:.1f}M")
    tcfg = TrainerConfig(
        num_steps=args.steps,
        checkpoint_every=max(args.steps // 4, 10),
        log_every=max(args.steps // 20, 1),
        seq_len=args.seq,
        global_batch=args.batch,
        lr=3e-4,
        warmup=20,
        fail_at_step=args.steps // 2 if args.fail else None,
    )
    t0 = time.time()
    with Trainer(cfg, tcfg, args.ckpt) as tr:
        out = tr.run_with_restarts() if args.fail else tr.run(resume=False)
    dt = time.time() - t0
    first, last = out["metrics"][0], out["metrics"][-1]
    toks = args.seq * args.batch * args.steps
    print(f"\nsteps={args.steps} wall={dt:.1f}s  tokens/s={toks / dt:,.0f}")
    print(f"loss: {first['loss']:.4f} (step {first['step']}) -> "
          f"{last['loss']:.4f} (step {last['step']})")
    assert last["loss"] < first["loss"], "loss did not decrease"


if __name__ == "__main__":
    main()
