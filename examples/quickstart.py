"""Quickstart: the paper's async-task and task-graph API (paper §4).

Runs the (a+b)*(c+d) task graph from the paper, then a recursive-Fibonacci
task graph, on the work-stealing pool.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import Task, TaskGraph, ThreadPool


def async_task_demo() -> None:
    # paper §4.1: submit a lambda, eventually executed by a worker
    with ThreadPool() as thread_pool:
        thread_pool.Submit(lambda: print("Completed"))
        thread_pool.wait_idle()


def task_graph_demo() -> None:
    # paper §4.2: (a + b) * (c + d) with every operation as a task
    vals = {}
    tasks = TaskGraph("arith")
    get_a = tasks.emplace_back(lambda: vals.__setitem__("a", 1))
    get_b = tasks.emplace_back(lambda: vals.__setitem__("b", 2))
    get_c = tasks.emplace_back(lambda: vals.__setitem__("c", 3))
    get_d = tasks.emplace_back(lambda: vals.__setitem__("d", 4))
    get_sum_ab = tasks.emplace_back(lambda: vals.__setitem__("ab", vals["a"] + vals["b"]))
    get_sum_cd = tasks.emplace_back(lambda: vals.__setitem__("cd", vals["c"] + vals["d"]))
    get_product = tasks.emplace_back(lambda: vals.__setitem__("p", vals["ab"] * vals["cd"]))

    get_sum_ab.Succeed(get_a, get_b)
    get_sum_cd.Succeed(get_c, get_d)
    get_product.Succeed(get_sum_ab, get_sum_cd)

    with ThreadPool() as thread_pool:
        thread_pool.Submit(tasks)
        thread_pool.wait_idle()
    print(f"(a+b)*(c+d) = {vals['p']}")
    assert vals["p"] == 21


def fibonacci_demo(n: int = 18) -> None:
    # the paper's benchmark workload: the full fib(n) recursion DAG
    results = {}
    g = TaskGraph("fib")

    def build(n: int, key: str) -> Task:
        if n < 2:
            return g.add(lambda k=key, v=n: results.__setitem__(k, v))
        left = build(n - 1, key + "l")
        right = build(n - 2, key + "r")
        return g.add(
            lambda k=key: results.__setitem__(k, results[k + "l"] + results[k + "r"])
        ).succeed(left, right)

    build(n, "r")
    t0 = time.perf_counter()
    with ThreadPool() as pool:
        pool.run(g)
    dt = time.perf_counter() - t0
    print(f"fib({n}) = {results['r']}  [{len(g)} tasks in {dt * 1e3:.1f} ms, "
          f"{dt / len(g) * 1e6:.2f} us/task]")


if __name__ == "__main__":
    async_task_demo()
    task_graph_demo()
    fibonacci_demo()
