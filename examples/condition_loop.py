"""Condition-task loops (DESIGN.md §10): iterative convergence in the graph.

Jacobi-style relaxation of a 1-D heat profile: one pass of the stencil is a
fan-out of row tasks, and a **condition task** closes the cycle with a weak
back-edge — while the residual is above tolerance it returns branch 0 (loop)
and the next pass starts inside the worker pool, with no Python-side
resubmission; once converged it returns out-of-range and the run drains.
A condition releases exactly one branch, so the back edge re-enters through
a single ``reenter`` task that fans out strongly to every row:

    entry -> reenter -> [rows ...] -> residual -> converged? --(exit)--> done
                ^_________________________________________|
                          branch 0 (weak back-edge)

Also shows the Python-side companion, ``Executor.run_until``, for loops
whose convergence check lives outside the graph.

    PYTHONPATH=src python examples/condition_loop.py
"""
import numpy as np

from repro.core import Executor, TaskGraph


def in_graph_loop(ex: Executor, n: int = 128, tol: float = 1e-4) -> None:
    field = np.linspace(0.0, 1.0, n) + np.sin(np.linspace(0, 20, n)) * 0.3
    state = {"passes": 0, "residual": np.inf}
    chunks = 4
    bounds = [
        (max(i * n // chunks, 1), min((i + 1) * n // chunks, n - 1)) for i in range(chunks)
    ]
    scratch = field.copy()

    g = TaskGraph("jacobi")
    entry = g.add(lambda: state.update(passes=0, residual=np.inf), name="entry")
    # the loop's single re-entry point: reached strongly from entry on the
    # first pass, weakly from the condition's back-edge on every other
    reenter = g.add(None, name="reenter")
    reenter.after(entry)

    def relax(lo: int, hi: int) -> None:
        scratch[lo:hi] = 0.5 * (field[lo - 1 : hi - 1] + field[lo + 1 : hi + 1])

    rows = [g.add(lambda b=b: relax(*b), name=f"rows{i}") for i, b in enumerate(bounds)]
    for r in rows:
        r.after(reenter)

    def residual() -> None:
        state["residual"] = float(np.abs(scratch[1:-1] - field[1:-1]).max())
        field[1:-1] = scratch[1:-1]
        state["passes"] += 1

    res = g.add(residual, name="residual")
    res.after(*rows)

    def converged() -> int:
        return 1 if state["residual"] < tol else 0  # 1 = out-of-range = exit

    cond = g.add(converged, kind="condition", name="converged?")
    cond.after(res)
    cond.precede(reenter)  # branch 0: weak back-edge -> next pass

    g.validate()  # condition-closed cycles are legal; strong cycles are not
    ex.run(g).result(120)
    print(
        f"in-graph condition loop: converged in {state['passes']} passes "
        f"(residual {state['residual']:.2e}, graph of {len(g)} tasks, 1 submission)"
    )
    assert state["residual"] < tol


def run_until_loop(ex: Executor, x0: float = 1234.5) -> None:
    # Newton iteration for sqrt(x0); the convergence check lives caller-side
    state = {"y": x0}
    g = TaskGraph("newton")
    g.add(lambda: state.update(y=0.5 * (state["y"] + x0 / state["y"])))
    rounds = ex.run_until(g, lambda: abs(state["y"] ** 2 - x0) < 1e-9, max_rounds=64)
    print(f"run_until: sqrt({x0}) = {state['y']:.6f} in {rounds} rounds")
    assert abs(state["y"] - np.sqrt(x0)) < 1e-6


def main() -> None:
    with Executor(4) as ex:
        in_graph_loop(ex)
        run_until_loop(ex)


if __name__ == "__main__":
    main()
