"""Process backend demo: the same graph, CPU-bound bodies, three backends.

A fan-out of pure-Python compute bodies is the workload the GIL
serializes: on the thread backend the four bodies below run one at a
time no matter how many workers the pool has, while
``Executor(backend="process")`` ships each body to a worker process and
they genuinely run on separate cores (DESIGN.md §11). The graph is built
ONCE — the backend is a constructor switch, not an API change.

    PYTHONPATH=src python examples/process_backend.py [--iters 400000]

Expected output shape (host-dependent — the speedup scales with real
cores; a contended 2-vCPU CI box shows ~1.3-1.6x, a dedicated 4-core
host 2-3x):

    serial     1 worker      182.4 ms   (floor)
    thread     2 workers     211.7 ms   0.86x vs serial
    process    2 workers     117.3 ms   1.55x vs serial, 1.80x vs thread
"""
from __future__ import annotations

import argparse
import os
import time

from repro.core import Executor, TaskGraph


def burn(iters: int) -> float:
    """Pure-Python compute: holds the GIL for its entire duration."""
    x = 0.0
    for i in range(iters):
        x += (i * i) % 7
    return x


def build(g: TaskGraph, width: int, iters: int):
    """root -> `width` independent burns -> gathered total."""
    root = g.add(lambda: None, name="root")
    layer = [
        g.add(lambda n=iters: burn(n), name=f"burn{i}").after(root)
        for i in range(width)
    ]
    return g.gather(layer, fn=lambda *vs: sum(vs), name="total")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=400_000, help="work per body")
    ap.add_argument("--width", type=int, default=2 * (os.cpu_count() or 1))
    args = ap.parse_args()

    cores = os.cpu_count() or 1
    walls: dict[str, float] = {}
    expected = None
    for backend in ("serial", "thread", "process"):
        g = TaskGraph(f"cpu-bound-{backend}")
        total = build(g, args.width, args.iters)
        workers = 1 if backend == "serial" else cores
        with Executor(workers, backend=backend) as ex:
            best = float("inf")
            for _ in range(2):
                g.reset()
                t0 = time.perf_counter()
                ex.run(g).result(300)
                best = min(best, time.perf_counter() - t0)
        walls[backend] = best
        if expected is None:
            expected = total.result
        assert total.result == expected, "backends must compute identical results"
        vs = (
            "(floor)"
            if backend == "serial"
            else f"{walls['serial'] / best:.2f}x vs serial"
            + (f", {walls['thread'] / best:.2f}x vs thread" if backend == "process" else "")
        )
        print(f"{backend:<10} {workers} worker{'s' if workers > 1 else ' '}"
              f" {best * 1e3:9.1f} ms   {vs}")

    speedup = walls["thread"] / walls["process"]
    print(f"\nprocess backend: {speedup:.2f}x faster than thread on "
          f"{args.width} x burn({args.iters}) across {cores} cores")
    # the GIL guarantees threads cannot parallelize these bodies; processes
    # must at least match them (they beat them by ~cores on dedicated hosts)
    assert speedup > 0.9, f"process backend slower than thread ({speedup:.2f}x)"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
